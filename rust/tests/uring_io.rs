//! PR-7 acceptance: io_uring-backed async I/O under the Backend trait.
//!
//! - Property: with `EngineConfig::io_uring` on, the persisted files AND
//!   the restored bytes are BYTE-IDENTICAL to the thread-pool path
//!   across random chunk/lane/queue-depth/coalesce configs. The test is
//!   meaningful on every kernel: where io_uring is available the two
//!   sides take genuinely different data paths; where the probe fails,
//!   the uring side falls back and identity holds by construction —
//!   which is exactly the fallback contract under test.
//! - Fault injection (pure helpers, no ring required — so resubmission
//!   logic is verified even on sandboxed kernels): short writes/reads
//!   advance their windows and converge, `EINTR`/`EAGAIN`/`ECANCELED`
//!   resubmit unchanged, zero progress fails instead of spinning.
//! - Mid-run ring teardown: dropping the context with completions still
//!   in flight fires the run's callback — drained or failed, never hung.

use datastates::config::EngineConfig;
use datastates::engine::{CheckpointEngine, DataStatesEngine};
use datastates::restore::{ReadEngine, ReadEngineConfig};
use datastates::state::shard::FileKind;
use datastates::state::tensor::{DType, SimDeviceTensor, TensorShard};
use datastates::state::{PyObj, RankState, ShardFile, StateItem};
use datastates::storage::uring::{advance_windows, classify_cqe,
                                 split_read_windows, CqeAction, EAGAIN,
                                 ECANCELED, EINTR, EIO};
use datastates::storage::UringContext;
use datastates::util::{proptest, Rng, TempDir};

/// A small multi-file state mixing device and host tensors so both the
/// D2H staging lanes and the direct path feed the gather runs.
fn sample_state(rng: &mut Rng) -> RankState {
    let n_files = rng.range(2, 5);
    let mut files = Vec::new();
    for f in 0..n_files {
        let mut items = Vec::new();
        for i in 0..rng.range(2, 5) {
            let len = rng.range(2_000, 80_000);
            let data: Vec<u8> = (0..len)
                .map(|j| ((f * 41 + i * 113 + j * 11) % 249) as u8)
                .collect();
            items.push(StateItem::Tensor(if i % 2 == 0 {
                TensorShard::device(
                    format!("d{f}_{i}"),
                    DType::U8,
                    vec![len],
                    SimDeviceTensor::new(data),
                )
            } else {
                TensorShard::host(format!("h{f}_{i}"), DType::U8,
                                  vec![len], data)
            }));
        }
        items.push(StateItem::Object {
            name: format!("opt{f}"),
            obj: PyObj::synthetic_metadata(rng.range(300, 4_000), 23),
        });
        files.push(ShardFile {
            name: format!("shard_{f:02}.pt"),
            kind: FileKind::ParamLayer,
            items,
        });
    }
    RankState { rank: 0, files }
}

#[test]
fn uring_path_is_byte_identical_to_thread_pool_path() {
    proptest::check(0x0716, 5, |rng| {
        let state = sample_state(rng);
        let chunk = rng.range(512, 32_768);
        let lanes = rng.range(1, 4);
        let depth = *rng.choose(&[2usize, 8, 64, 256]);

        // persist the SAME state twice: thread-pool path, then uring
        let mut worlds = Vec::new();
        for io_uring in [false, true] {
            let dir = TempDir::new("uring-prop")?;
            let mut cfg = EngineConfig::with_dir(dir.path());
            cfg.host_cache_bytes = 16 << 20;
            cfg.chunk_bytes = chunk;
            cfg.stager_lanes = lanes;
            cfg.io_uring = io_uring;
            cfg.uring_queue_depth = depth;
            let mut eng = DataStatesEngine::new(cfg)?;
            let ticket = eng.begin(0, &state)?;
            ticket.wait_persisted()?;
            worlds.push((dir, eng.pipeline()));
        }

        // identical file sets with identical on-disk bytes
        let list = |d: &std::path::Path| -> anyhow::Result<Vec<String>> {
            let mut names: Vec<String> = std::fs::read_dir(d)?
                .map(|e| {
                    Ok(e?.file_name().to_string_lossy().into_owned())
                })
                .collect::<anyhow::Result<_>>()?;
            names.sort();
            Ok(names)
        };
        let base = worlds[0].0.path().join("v000000");
        let ring = worlds[1].0.path().join("v000000");
        let names = list(&base)?;
        anyhow::ensure!(names == list(&ring)?,
                        "file sets diverge (chunk={chunk})");
        for n in &names {
            anyhow::ensure!(
                std::fs::read(base.join(n))?
                    == std::fs::read(ring.join(n))?,
                "{n} differs on disk (chunk={chunk} depth={depth})"
            );
        }

        // restores through both pipelines agree byte-for-byte AND with
        // the source state, under a random read shape
        let rcfg = ReadEngineConfig {
            readers: rng.range(1, 6),
            restore_lanes: rng.range(1, 4),
            coalesce_bytes: *rng.choose(&[0usize, 32 << 10, 16 << 20]),
            ..Default::default()
        };
        let rd_base = ReadEngine::new(rcfg.clone());
        let rd_ring = ReadEngine::new(rcfg.clone());
        let a = rd_base.read_version(&worlds[0].1, 0)?;
        let b = rd_ring.read_version(&worlds[1].1, 0)?;
        anyhow::ensure!(a.len() == b.len());
        for (name, rf) in &a {
            anyhow::ensure!(b[name].payloads == rf.payloads,
                            "{name} restores differently under {rcfg:?}");
        }
        datastates::restore::verify_files_against(&b, &state)?;

        // attribution: the ring only claims work where it could run
        let u = worlds[1].1.uring_stats().unwrap_or_default();
        let rm = rd_ring.metrics();
        if UringContext::available() {
            anyhow::ensure!(u.active() && u.sqes >= u.submits,
                            "uring on + available but idle: {u:?}");
            anyhow::ensure!(rm.uring_submits > 0
                                && rm.uring_sqes >= rm.uring_submits,
                            "restore pass missed ring deltas: {rm:?}");
        } else {
            anyhow::ensure!(!u.active(), "fallback claimed ring work");
            anyhow::ensure!(rm.uring_submits == 0 && rm.uring_sqes == 0);
        }
        let v = worlds[0].1.uring_stats().unwrap_or_default();
        anyhow::ensure!(!v.active(),
                        "thread-pool pipeline claimed ring work");
        Ok(())
    });
}

#[test]
fn short_writes_advance_their_windows_until_the_run_converges() {
    // a device that lands at most 7 bytes per submission: every CQE is
    // a short write; the op must advance exactly that far and resubmit
    let mut windows = vec![(0x1000u64, 10usize), (0x2000, 20)];
    let mut resubmits = 0;
    loop {
        let expected: usize = windows.iter().map(|w| w.1).sum();
        let landed = expected.min(7);
        match classify_cqe(landed as i32, expected) {
            CqeAction::Done => break,
            CqeAction::Advance(n) => {
                assert_eq!(n, 7);
                advance_windows(&mut windows, n);
                resubmits += 1;
            }
            other => panic!("short write classified as {other:?}"),
        }
        assert!(resubmits <= 30, "short-write loop did not converge");
    }
    // 30 bytes at 7 per turn: 4 shorts, then the final 2 complete
    assert_eq!(resubmits, 4);
    assert_eq!(windows.iter().map(|w| w.1).sum::<usize>(), 2);
    // the surviving window kept its file-relative position
    assert_eq!(windows, vec![(0x2000 + 18, 2)]);
}

#[test]
fn transient_errors_resubmit_unchanged_and_hard_errors_fail() {
    for e in [EINTR, EAGAIN, ECANCELED] {
        assert_eq!(classify_cqe(-e, 4096), CqeAction::Resubmit,
                   "errno {e} must resubmit");
    }
    // a transient resubmission advances NOTHING — same windows go back
    let mut w = vec![(0u64, 100usize), (500, 50)];
    let before = w.clone();
    advance_windows(&mut w, 0);
    assert_eq!(w, before);
    // zero progress on a non-empty op is EOF/dead-device, not a retry
    assert_eq!(classify_cqe(0, 4096), CqeAction::Fail(EIO));
    // hard errors carry their errno out to the run
    assert_eq!(classify_cqe(-13, 4096), CqeAction::Fail(13));
}

#[test]
fn read_splitting_covers_random_window_sets_exactly() {
    proptest::check(0x517C, 8, |rng| {
        let n = rng.range(1, 8);
        let mut dsts = Vec::new();
        let mut addr = 0u64;
        for _ in 0..n {
            let len = rng.range(1, 1 << 20);
            dsts.push((addr, len));
            // leave gaps so adjacency never hides coverage bugs
            addr += len as u64 + rng.range(1, 4096) as u64;
        }
        let slice = rng.range(1, 512 << 10);
        let out = split_read_windows(&dsts, slice);
        anyhow::ensure!(out.iter().all(|&(_, l)| l <= slice && l > 0));
        let total: usize = out.iter().map(|&(_, l)| l).sum();
        let want: usize = dsts.iter().map(|&(_, l)| l).sum();
        anyhow::ensure!(total == want, "split lost bytes");
        // ops walk each source window front-to-back with no overlap
        let mut it = out.iter();
        for &(start, len) in &dsts {
            let mut off = 0usize;
            while off < len {
                let &(a, l) = it.next().unwrap();
                anyhow::ensure!(a == start + off as u64,
                                "op out of order");
                off += l;
            }
            anyhow::ensure!(off == len);
        }
        Ok(())
    });
}

#[cfg(target_os = "linux")]
#[test]
fn mid_run_teardown_still_fires_the_completion() {
    // Probe-gated: on kernels without io_uring there is no ring to tear
    // down and the fallback contract is covered by the property above.
    if !UringContext::available() {
        return;
    }
    use datastates::provider::Bytes;
    use std::os::unix::io::AsRawFd;
    let dir = TempDir::new("uring-teardown").unwrap();
    let path = dir.path().join("f");
    let file = std::fs::OpenOptions::new()
        .create(true)
        .truncate(true)
        .read(true)
        .write(true)
        .open(&path)
        .unwrap();
    let ctx = UringContext::new(4).unwrap();
    let (tx, rx) = std::sync::mpsc::channel();
    // a run far wider than the queue: completions are still in flight
    // (and slots still cycling) when the context drops right after
    let extents: Vec<Bytes> = (0..64)
        .map(|i| Bytes::from_vec(vec![i as u8; 32 << 10]))
        .collect();
    ctx.submit_write(
        file.as_raw_fd(),
        0,
        extents,
        Box::new(move |r| {
            let _ = tx.send(r.is_ok());
        }),
    );
    drop(ctx);
    // the callback MUST fire — drained to disk or failed as torn down,
    // but never left hanging on a dead ring
    let ok = rx
        .recv_timeout(std::time::Duration::from_secs(20))
        .expect("teardown left the run's completion hanging");
    if ok {
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 64 * (32 << 10));
        for (i, chunk) in bytes.chunks(32 << 10).enumerate() {
            assert!(chunk.iter().all(|&b| b == i as u8),
                    "extent {i} torn");
        }
    }
}
