//! Content-addressed remote tier (ROADMAP item 2; legion
//! `lgn-content-store`-style Provider + manifest split).
//!
//! The drain pipeline's terminal tier used to be a plain filesystem, so
//! every checkpoint version paid full-model bytes to the slowest device
//! even though adjacent versions share most of their parameter bytes.
//! This module makes the terminal hop *content-addressed*:
//!
//! - [`ChunkId`] / [`xxh64`] — fixed-size chunks keyed by an XXH64
//!   content checksum (computed on the drain worker, shared with the
//!   delta provider's block fingerprints).
//! - [`ChunkStore`] — a write-once blob store (`objects/x<hash>-<len>`)
//!   with refcounted GC: a chunk is uploaded at most once no matter how
//!   many versions or files reference it, and deleted only when the
//!   last reference is released.
//! - [`ContentManifest`] — the file → chunk-list map, rewritten whole
//!   through a temp file + atomic rename (the same discipline the
//!   cross-tier MANIFEST uses) so a crash can never tear it.
//! - [`RemoteStore`] — a [`super::Backend`] over the chunk store with a
//!   simulated per-request latency + bandwidth shim
//!   (`--tiers remote:<latency_ms>:<mbps>`), so the tier pipeline
//!   drains into WAN-shaped costs and restores back out of them with
//!   per-chunk checksum verification.
//!
//! Incremental checkpoints fall out of the addressing: draining version
//! N+1 re-chunks each file, finds most chunk ids already present (clean
//! blocks hash identically), and uploads only the dirty ones — the
//! dedupe factor is surfaced per version in `CkptMetrics`
//! (`chunks_total` / `chunks_uploaded` / `dedup_bytes_skipped`).

pub mod manifest;
pub mod remote;
pub mod store;

pub use manifest::ContentManifest;
pub use remote::RemoteStore;
pub use store::ChunkStore;

/// Default content-chunk size: small enough that a sparse update dirties
/// a small byte fraction, large enough that per-chunk request latency
/// does not dominate uploads.
pub const DEFAULT_CONTENT_CHUNK_BYTES: usize = 256 << 10;

/// XXH64 (Yann Collet's xxHash, 64-bit variant) — the content checksum
/// keying the chunk store and the delta provider's block fingerprints.
/// Implemented in-tree (the build is offline); verified against the
/// reference test vectors below.
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    const P1: u64 = 0x9E37_79B1_85EB_CA87;
    const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    const P3: u64 = 0x1656_67B1_9E37_79F9;
    const P4: u64 = 0x85EB_CA77_C2B2_AE63;
    const P5: u64 = 0x27D4_EB2F_1656_67C5;

    #[inline]
    fn u64_at(b: &[u8]) -> u64 {
        u64::from_le_bytes(b[..8].try_into().unwrap())
    }
    #[inline]
    fn u32_at(b: &[u8]) -> u32 {
        u32::from_le_bytes(b[..4].try_into().unwrap())
    }
    #[inline]
    fn round(acc: u64, lane: u64) -> u64 {
        acc.wrapping_add(lane.wrapping_mul(P2))
            .rotate_left(31)
            .wrapping_mul(P1)
    }
    #[inline]
    fn merge(acc: u64, v: u64) -> u64 {
        (acc ^ round(0, v)).wrapping_mul(P1).wrapping_add(P4)
    }

    let mut rest = data;
    let mut h = if data.len() >= 32 {
        let mut v1 = seed.wrapping_add(P1).wrapping_add(P2);
        let mut v2 = seed.wrapping_add(P2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(P1);
        while rest.len() >= 32 {
            v1 = round(v1, u64_at(&rest[0..]));
            v2 = round(v2, u64_at(&rest[8..]));
            v3 = round(v3, u64_at(&rest[16..]));
            v4 = round(v4, u64_at(&rest[24..]));
            rest = &rest[32..];
        }
        let mut h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge(h, v1);
        h = merge(h, v2);
        h = merge(h, v3);
        merge(h, v4)
    } else {
        seed.wrapping_add(P5)
    };
    h = h.wrapping_add(data.len() as u64);
    while rest.len() >= 8 {
        h = (h ^ round(0, u64_at(rest)))
            .rotate_left(27)
            .wrapping_mul(P1)
            .wrapping_add(P4);
        rest = &rest[8..];
    }
    if rest.len() >= 4 {
        h = (h ^ (u32_at(rest) as u64).wrapping_mul(P1))
            .rotate_left(23)
            .wrapping_mul(P2)
            .wrapping_add(P3);
        rest = &rest[4..];
    }
    for &b in rest {
        h = (h ^ (b as u64).wrapping_mul(P5))
            .rotate_left(11)
            .wrapping_mul(P1);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^= h >> 32;
    h
}

/// Identity of one stored chunk: content checksum + exact length. The
/// length rides along so two chunks that collide on checksum but differ
/// in size can never alias, and so readers can plan extents without
/// fetching blobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId {
    pub hash: u64,
    pub len: u32,
}

impl ChunkId {
    /// Address of a chunk of bytes.
    pub fn of(data: &[u8]) -> ChunkId {
        ChunkId { hash: xxh64(data, 0), len: data.len() as u32 }
    }

    /// Blob object name under the store's `objects/` directory.
    pub fn object_name(&self) -> String {
        format!("x{:016x}-{:08x}", self.hash, self.len)
    }

    /// Parse an `objects/` blob name back into an id.
    pub fn parse_object_name(name: &str) -> Option<ChunkId> {
        let rest = name.strip_prefix('x')?;
        let (h, l) = rest.split_once('-')?;
        Some(ChunkId {
            hash: u64::from_str_radix(h, 16).ok()?,
            len: u32::from_str_radix(l, 16).ok()?,
        })
    }
}

impl std::fmt::Display for ChunkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}/{}B", self.hash, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xxh64_reference_vectors() {
        // reference vectors from the xxHash specification
        assert_eq!(xxh64(b"", 0), 0xEF46_DB37_51D8_E999);
        assert_eq!(xxh64(b"abc", 0), 0x44BC_2CF5_AD77_0999);
        // cross-length sanity: every code path (>=32B loop, 8/4/1-byte
        // tails) produces distinct, length-sensitive digests
        let data: Vec<u8> = (0..256u32).map(|i| (i % 251) as u8).collect();
        let mut seen = std::collections::HashSet::new();
        for n in [0, 1, 3, 4, 7, 8, 31, 32, 33, 63, 64, 100, 256] {
            assert!(seen.insert(xxh64(&data[..n], 0)), "collision at {n}");
        }
        // seed changes the digest
        assert_ne!(xxh64(b"abc", 0), xxh64(b"abc", 1));
    }

    #[test]
    fn chunk_id_object_name_roundtrip() {
        let id = ChunkId::of(b"hello chunk");
        let name = id.object_name();
        assert_eq!(ChunkId::parse_object_name(&name), Some(id));
        assert_eq!(ChunkId::parse_object_name("not-a-chunk"), None);
        assert_eq!(ChunkId::parse_object_name("xzz-11"), None);
        // same bytes, same id; different length, different id
        assert_eq!(ChunkId::of(b"hello chunk"), id);
        assert_ne!(ChunkId::of(b"hello chunk!"), id);
    }
}
