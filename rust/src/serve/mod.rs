//! Checkpoint serving at scale (paper §V scaled out to many
//! concurrent consumers): a [`CheckpointService`] owns one
//! `Arc`-shared [`TierPipeline`] per source rank and serves N
//! concurrent writers plus M concurrent readers — restore, reshard and
//! verify sessions — from ONE set of tier backends, so reads and
//! checkpoint writes contend for the same modeled devices instead of
//! each session pretending it owns the machine.
//!
//! Three mechanisms make that scale:
//!
//! - **Admission + weighted QoS** ([`Qos`], [`Admission`]): at most
//!   `max_inflight` requests run at once (the rest queue, wait time
//!   reported per request), and each QoS class charges the per-tier
//!   [`crate::storage::Throttle`]s at its weight — interactive probes
//!   slip between a background sweep's bandwidth quanta instead of
//!   convoying behind them.
//! - **Shared gather-run read cache** ([`RunCache`]): sealed runs are
//!   cached across sessions with single-flight fill dedup, so K
//!   simultaneous restores of one version cost ~one backing read per
//!   run.
//! - **Persistent read engines**: one lazily-built
//!   [`crate::restore::ReadEngine`] per QoS class, reader/lane threads
//!   and staging pool reused across every request it serves (no
//!   per-request thread churn).

mod cache;

pub use cache::{RunCache, RunCacheStats, RunKey};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::restore::reshard::{CheckpointWorld, ReshardPlan};
use crate::restore::{PassReport, ReadEngine, ReadEngineConfig};
use crate::state::RankState;
use crate::storage::{RestoredVersion, TierPipeline};

/// Service quality classes, ordered interactive-first. The weight is
/// the class's throttle-quantum multiplier (see
/// [`crate::storage::Throttle::acquire_weighted`]): 16:1 between
/// interactive and background.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Qos {
    /// Latency-sensitive sessions (a rank waiting to resume training).
    Interactive,
    /// The default class.
    Standard,
    /// Bulk/scrub traffic (verify sweeps, migration drains).
    Background,
}

impl Qos {
    pub const ALL: [Qos; 3] =
        [Qos::Interactive, Qos::Standard, Qos::Background];

    /// Throttle weight of this class.
    pub fn weight(self) -> f64 {
        match self {
            Qos::Interactive => 4.0,
            Qos::Standard => 1.0,
            Qos::Background => 0.25,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Qos::Interactive => "interactive",
            Qos::Standard => "standard",
            Qos::Background => "background",
        }
    }

    /// Parse a CLI label (`--qos interactive|standard|background`).
    pub fn parse(s: &str) -> anyhow::Result<Qos> {
        match s {
            "interactive" => Ok(Qos::Interactive),
            "standard" => Ok(Qos::Standard),
            "background" => Ok(Qos::Background),
            other => anyhow::bail!(
                "unknown QoS class {other:?} (want \
                 interactive|standard|background)"
            ),
        }
    }

    fn idx(self) -> usize {
        match self {
            Qos::Interactive => 0,
            Qos::Standard => 1,
            Qos::Background => 2,
        }
    }
}

/// Serving-plane knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Read-engine geometry shared by every QoS class's engine.
    pub read: ReadEngineConfig,
    /// Gather-run cache capacity; `0` disables caching (ablation).
    pub run_cache_bytes: u64,
    /// Admission bound: requests running at once (the rest queue).
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            read: ReadEngineConfig::default(),
            run_cache_bytes: 256 << 20,
            max_inflight: 64,
        }
    }
}

/// One served read: the restored files plus the request's admission
/// wait and its pass latency/cache report.
#[derive(Debug)]
pub struct ServedRead {
    pub files: RestoredVersion,
    /// Time queued in admission before the pass started.
    pub wait_s: f64,
    pub report: PassReport,
    pub qos: Qos,
}

/// One served reshard execution (see [`ServedRead`]).
#[derive(Debug)]
pub struct ServedPlan {
    pub ranks: Vec<RankState>,
    pub wait_s: f64,
    pub report: PassReport,
    pub qos: Qos,
}

/// Counting-semaphore admission gate with wait-time measurement.
struct Admission {
    permits: Mutex<usize>,
    cv: Condvar,
}

struct AdmissionGuard<'a> {
    gate: &'a Admission,
}

impl Admission {
    fn new(n: usize) -> Admission {
        Admission { permits: Mutex::new(n.max(1)), cv: Condvar::new() }
    }

    /// Block until admitted; returns the guard and the queue wait.
    fn acquire(&self) -> (AdmissionGuard<'_>, f64) {
        let t0 = Instant::now();
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        (AdmissionGuard { gate: self }, t0.elapsed().as_secs_f64())
    }
}

impl Drop for AdmissionGuard<'_> {
    fn drop(&mut self) {
        *self.gate.permits.lock().unwrap() += 1;
        self.gate.cv.notify_one();
    }
}

/// Aggregate serving counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    /// Requests per QoS class, indexed `[interactive, standard,
    /// background]`.
    pub by_class: [u64; 3],
    /// Run-cache counters (`None` when serving uncached).
    pub cache: Option<RunCacheStats>,
}

/// The shared-pipeline checkpoint service (module docs above). Cheap to
/// share: hand `Arc<CheckpointService>` clones to every session thread.
pub struct CheckpointService {
    pipelines: Vec<Arc<TierPipeline>>,
    cache: Option<Arc<RunCache>>,
    cfg: ServeConfig,
    admission: Admission,
    /// One persistent engine per QoS class, built on first use.
    engines: Mutex<HashMap<usize, Arc<ReadEngine>>>,
    requests: AtomicU64,
    by_class: [AtomicU64; 3],
}

impl CheckpointService {
    /// Serve the given source-rank pipelines. The `Arc`s may (and for
    /// live serving, should) be the same pipelines a writer engine is
    /// checkpointing through — shared tiers mean shared throttles mean
    /// real reader/writer contention.
    pub fn new(pipelines: Vec<Arc<TierPipeline>>, cfg: ServeConfig)
        -> Arc<CheckpointService> {
        let cache = if cfg.run_cache_bytes > 0 {
            Some(RunCache::new(cfg.run_cache_bytes))
        } else {
            None
        };
        Arc::new(CheckpointService {
            admission: Admission::new(cfg.max_inflight),
            pipelines,
            cache,
            cfg,
            engines: Mutex::new(HashMap::new()),
            requests: AtomicU64::new(0),
            by_class: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        })
    }

    /// Serve a resolved [`CheckpointWorld`] — including one opened
    /// with `CheckpointWorld::open_replicated`, whose per-rank
    /// pipelines fall through to peer replica copies: served reads
    /// survive a lost or torn source rank exactly like restores do,
    /// under the same QoS admission and run cache.
    pub fn from_world(world: &CheckpointWorld, cfg: ServeConfig)
        -> Arc<CheckpointService> {
        Self::new(world.pipelines(), cfg)
    }

    /// Number of source ranks served.
    pub fn ranks(&self) -> usize {
        self.pipelines.len()
    }

    /// One source rank's pipeline.
    pub fn pipeline(&self, rank: usize)
        -> anyhow::Result<&Arc<TierPipeline>> {
        self.pipelines.get(rank).ok_or_else(|| {
            anyhow::anyhow!(
                "service has no source rank {rank} (serving {} ranks)",
                self.pipelines.len()
            )
        })
    }

    /// A reshard world over the SAME pipeline `Arc`s this service
    /// serves — reshard sessions share run-cache namespaces (and tier
    /// throttles) with restore sessions.
    pub fn world(&self) -> CheckpointWorld {
        CheckpointWorld::from_pipelines(self.pipelines.clone())
    }

    /// The run cache, if serving cached.
    pub fn run_cache(&self) -> Option<&Arc<RunCache>> {
        self.cache.as_ref()
    }

    /// The persistent read engine of one QoS class (built on first
    /// use; all classes share the one run cache).
    fn engine_for(&self, qos: Qos) -> Arc<ReadEngine> {
        let mut engines = self.engines.lock().unwrap();
        engines
            .entry(qos.idx())
            .or_insert_with(|| {
                let mut eng = ReadEngine::new(self.cfg.read.clone())
                    .with_qos_weight(qos.weight());
                if let Some(cache) = &self.cache {
                    eng = eng.with_run_cache(cache.clone());
                }
                Arc::new(eng)
            })
            .clone()
    }

    fn count(&self, qos: Qos) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.by_class[qos.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Serve one full-version restore of one source rank.
    pub fn read_version(&self, rank: usize, version: u64, qos: Qos)
        -> anyhow::Result<ServedRead> {
        let pipeline = self.pipeline(rank)?.clone();
        let engine = self.engine_for(qos);
        let (_admitted, wait_s) = self.admission.acquire();
        self.count(qos);
        let (files, report) =
            engine.read_version_report(&pipeline, version)?;
        Ok(ServedRead { files, wait_s, report, qos })
    }

    /// Serve one reshard-plan execution across the service's ranks.
    pub fn execute_plan(&self, version: u64, plan: &ReshardPlan,
                        qos: Qos) -> anyhow::Result<ServedPlan> {
        let world = self.world();
        let engine = self.engine_for(qos);
        let (_admitted, wait_s) = self.admission.acquire();
        self.count(qos);
        let (ranks, report) =
            engine.execute_plan_report(&world, version, plan)?;
        Ok(ServedPlan { ranks, wait_s, report, qos })
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            by_class: [
                self.by_class[0].load(Ordering::Relaxed),
                self.by_class[1].load(Ordering::Relaxed),
                self.by_class[2].load(Ordering::Relaxed),
            ],
            cache: self.cache.as_ref().map(|c| c.stats()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qos_parses_and_orders_weights() {
        for q in Qos::ALL {
            assert_eq!(Qos::parse(q.label()).unwrap(), q);
        }
        assert!(Qos::parse("realtime").is_err());
        assert!(Qos::Interactive.weight() > Qos::Standard.weight());
        assert!(Qos::Standard.weight() > Qos::Background.weight());
    }

    #[test]
    fn admission_bounds_inflight_and_measures_wait() {
        let gate = Arc::new(Admission::new(1));
        let (g, w) = gate.acquire();
        assert!(w < 0.05);
        let gate2 = gate.clone();
        let h = std::thread::spawn(move || gate2.acquire().1);
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(g);
        let waited = h.join().unwrap();
        assert!(waited >= 0.03,
                "second request should have queued: {waited}");
    }

    #[test]
    fn service_rejects_unknown_rank() {
        let svc =
            CheckpointService::new(Vec::new(), ServeConfig::default());
        assert!(svc.read_version(0, 0, Qos::Standard).is_err());
        assert_eq!(svc.ranks(), 0);
        assert!(svc.stats().cache.is_some());
    }

    #[test]
    fn cache_off_config_serves_uncached() {
        let svc = CheckpointService::new(
            Vec::new(),
            ServeConfig { run_cache_bytes: 0, ..Default::default() },
        );
        assert!(svc.run_cache().is_none());
        assert!(svc.stats().cache.is_none());
    }
}
