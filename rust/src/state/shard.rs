//! Shard files and rank state: the unit the checkpoint engine consumes.
//!
//! A checkpoint on one rank is a set of [`ShardFile`]s (DeepSpeed writes
//! each as an independent file — layer shards, optimizer shard, metadata
//! shard). Each file mixes tensors and objects: the "cardinality" axis of
//! 3D heterogeneity.

use super::object::PyObj;
use super::tensor::TensorShard;

/// One logical item inside a shard file.
#[derive(Clone)]
pub enum StateItem {
    Tensor(TensorShard),
    Object { name: String, obj: PyObj },
}

impl StateItem {
    pub fn name(&self) -> &str {
        match self {
            StateItem::Tensor(t) => &t.name,
            StateItem::Object { name, .. } => name,
        }
    }

    /// Payload bytes (exact for tensors, approximate for objects until
    /// serialized).
    pub fn approx_bytes(&self) -> usize {
        match self {
            StateItem::Tensor(t) => t.size_bytes(),
            StateItem::Object { obj, .. } => obj.approx_size(),
        }
    }

    pub fn is_tensor(&self) -> bool {
        matches!(self, StateItem::Tensor(_))
    }
}

impl std::fmt::Debug for StateItem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateItem::Tensor(t) => write!(f, "{t:?}"),
            StateItem::Object { name, obj } => {
                write!(f, "Object({name}, ~{} B)", obj.approx_size())
            }
        }
    }
}

/// What role a shard file plays (drives Table I's census rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FileKind {
    /// `mp_rank_*_model_states.pt`: host-resident control state.
    Metadata,
    /// `layer_*-model_*-model_states.pt`: fp16 parameter shards.
    ParamLayer,
    /// `*_optim_states.pt`: fp32 optimizer partition (ZeRO-1).
    Optimizer,
}

/// One checkpoint file on one rank.
#[derive(Clone, Debug)]
pub struct ShardFile {
    /// File name relative to the checkpoint directory.
    pub name: String,
    pub kind: FileKind,
    pub items: Vec<StateItem>,
}

impl ShardFile {
    pub fn tensor_bytes(&self) -> usize {
        self.items
            .iter()
            .filter_map(|i| match i {
                StateItem::Tensor(t) => Some(t.size_bytes()),
                _ => None,
            })
            .sum()
    }

    pub fn object_bytes_approx(&self) -> usize {
        self.items
            .iter()
            .filter_map(|i| match i {
                StateItem::Object { obj, .. } => Some(obj.approx_size()),
                _ => None,
            })
            .sum()
    }

    pub fn num_tensors(&self) -> usize {
        self.items.iter().filter(|i| i.is_tensor()).count()
    }

    /// Bytes that still live on-device and need D2H staging.
    pub fn device_bytes(&self) -> usize {
        self.items
            .iter()
            .filter_map(|i| match i {
                StateItem::Tensor(t) if t.data.is_device() => {
                    Some(t.size_bytes())
                }
                _ => None,
            })
            .sum()
    }
}

/// All checkpoint files owned by one rank at one checkpoint request.
#[derive(Clone, Debug, Default)]
pub struct RankState {
    pub rank: usize,
    pub files: Vec<ShardFile>,
}

impl RankState {
    pub fn total_bytes(&self) -> usize {
        self.files
            .iter()
            .map(|f| f.tensor_bytes() + f.object_bytes_approx())
            .sum()
    }

    pub fn num_files(&self) -> usize {
        self.files.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::tensor::DType;

    #[test]
    fn shard_file_accounting() {
        let f = ShardFile {
            name: "layer_00.pt".into(),
            kind: FileKind::ParamLayer,
            items: vec![
                StateItem::Tensor(TensorShard::synthetic(
                    "w", DType::F16, vec![32, 32], 1)),
                StateItem::Object {
                    name: "meta".into(),
                    obj: PyObj::Dict(vec![("v".into(), PyObj::Int(1))]),
                },
            ],
        };
        assert_eq!(f.tensor_bytes(), 32 * 32 * 2);
        assert_eq!(f.num_tensors(), 1);
        assert!(f.object_bytes_approx() > 0);
        assert_eq!(f.device_bytes(), 0);
    }
}
