//! Read-side chunk streams: the restore-path mirror of the write-side
//! state providers.
//!
//! A [`ChunkSource`] opens one checkpoint file written in the hybrid
//! layout and exposes the SAME stream-oriented view the engine consumed
//! while writing it: a sequence of [`Chunk`]s ("N bytes that belong at
//! offset O"), produced by walking the [`FileLayout`] trailer entry by
//! entry, extent by extent. Restore pipelines can therefore be built
//! symmetrically to checkpoint pipelines — drain chunks, route them to
//! consumers by entry — instead of materializing whole files, and the
//! per-entry accessors reassemble payloads through positioned reads
//! exactly as the flush pool scattered them.
//!
//! The source is tier-agnostic: it reads through [`storage::ReadAt`],
//! so the same parser restores a checkpoint out of a real file OR out
//! of the in-memory host-cache tier ([`storage::Backend::open`]) — the
//! read-side mirror of the write-side tier pipeline.
//!
//! [`storage::ReadAt`]: crate::storage::ReadAt
//! [`storage::Backend::open`]: crate::storage::Backend::open

use std::fs::File;
use std::path::Path;

use crate::provider::layout::{FileLayout, FOOTER_BYTES};
use crate::provider::{Bytes, Chunk};
use crate::storage::ReadAt;

/// Default read granularity (matches the engine's default chunking).
pub(crate) const DEFAULT_CHUNK_BYTES: usize = 4 << 20;

/// A readable view over one checkpoint file's layout + payload extents.
pub struct ChunkSource {
    reader: Box<dyn ReadAt>,
    layout: FileLayout,
    chunk_bytes: usize,
    /// Stream position: (entry index, extent index, byte offset within
    /// the extent).
    entry_idx: usize,
    extent_idx: usize,
    extent_pos: u64,
}

impl ChunkSource {
    /// Open a checkpoint file and parse its footer + trailer.
    pub fn open(path: &Path) -> anyhow::Result<ChunkSource> {
        Self::with_chunk_bytes(path, DEFAULT_CHUNK_BYTES)
    }

    /// Open with an explicit streaming granularity.
    pub fn with_chunk_bytes(path: &Path, chunk_bytes: usize)
        -> anyhow::Result<ChunkSource> {
        Self::from_reader(Box::new(File::open(path)?), chunk_bytes)
            .map_err(|e| anyhow::anyhow!("{path:?}: {e:#}"))
    }

    /// Build over any positioned-read surface (a tier backend's
    /// [`crate::storage::Backend::open`] handle, a plain file, ...).
    pub fn from_reader(reader: Box<dyn ReadAt>, chunk_bytes: usize)
        -> anyhow::Result<ChunkSource> {
        let len = reader.len()?;
        anyhow::ensure!(len >= FOOTER_BYTES, "checkpoint too short");
        let mut footer = [0u8; FOOTER_BYTES as usize];
        reader.read_exact_at(&mut footer, len - FOOTER_BYTES)?;
        let (toff, tlen) = FileLayout::decode_footer(&footer)?;
        anyhow::ensure!(toff + tlen + FOOTER_BYTES <= len,
                        "trailer out of range");
        let mut trailer = vec![0u8; tlen as usize];
        reader.read_exact_at(&mut trailer, toff)?;
        let layout = FileLayout::decode_trailer(&trailer)?;
        Ok(ChunkSource {
            reader,
            layout,
            chunk_bytes: chunk_bytes.max(1),
            entry_idx: 0,
            extent_idx: 0,
            extent_pos: 0,
        })
    }

    /// The parsed self-describing layout.
    pub fn layout(&self) -> &FileLayout {
        &self.layout
    }

    /// Pull the next chunk of the stream, walking entries in trailer
    /// order and extents in logical order; `None` once exhausted. The
    /// chunk's `offset` is the absolute file offset (as on the write
    /// side) and its `label` is the owning entry's name.
    pub fn next_chunk(&mut self) -> anyhow::Result<Option<Chunk>> {
        loop {
            let Some(entry) = self.layout.entries.get(self.entry_idx)
            else {
                return Ok(None);
            };
            let Some(&(ext_off, ext_len)) =
                entry.extents.get(self.extent_idx)
            else {
                self.entry_idx += 1;
                self.extent_idx = 0;
                self.extent_pos = 0;
                continue;
            };
            if self.extent_pos >= ext_len {
                self.extent_idx += 1;
                self.extent_pos = 0;
                continue;
            }
            let take = (ext_len - self.extent_pos)
                .min(self.chunk_bytes as u64);
            let mut buf = vec![0u8; take as usize];
            self.reader
                .read_exact_at(&mut buf, ext_off + self.extent_pos)?;
            let chunk = Chunk {
                offset: ext_off + self.extent_pos,
                data: Bytes::from_vec(buf),
                label: entry.name.clone(),
            };
            self.extent_pos += take;
            return Ok(Some(chunk));
        }
    }

    fn read_extents(&self, entry: &crate::provider::LayoutEntry)
        -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::with_capacity(entry.total_len() as usize);
        for (off, len) in &entry.extents {
            let mut part = vec![0u8; *len as usize];
            self.reader.read_exact_at(&mut part, *off)?;
            out.extend_from_slice(&part);
        }
        Ok(out)
    }

    fn find_entry(&self, name: &str)
        -> anyhow::Result<&crate::provider::LayoutEntry> {
        self.layout
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("no entry {name}"))
    }

    /// Reassemble one entry's payload through positioned reads (extent
    /// order == logical order, exactly how the providers emitted it).
    pub fn read_entry(&self, name: &str) -> anyhow::Result<Vec<u8>> {
        self.read_extents(self.find_entry(name)?)
    }

    /// Read `len` payload bytes starting at entry-relative `offset`,
    /// through positioned reads of only the extents that overlap the
    /// requested window — the reshard executor's primitive: a target
    /// rank pulls exactly its slice of a source entry, never the whole
    /// file.
    pub fn read_entry_range(&self, name: &str, offset: u64, len: u64)
        -> anyhow::Result<Vec<u8>> {
        let mut out = vec![0u8; len as usize];
        self.read_entry_range_into(name, offset, &mut out)?;
        Ok(out)
    }

    /// [`ChunkSource::read_entry_range`] straight into a caller-owned
    /// buffer (`dst.len()` payload bytes at entry-relative `offset`) —
    /// the reshard executor reads each source slice directly into its
    /// slot of the target tensor, so the full checkpoint payload moves
    /// with a single copy and no per-slice temporaries.
    pub fn read_entry_range_into(&self, name: &str, offset: u64,
                                 dst: &mut [u8]) -> anyhow::Result<()> {
        let len = dst.len() as u64;
        let entry = self.find_entry(name)?;
        anyhow::ensure!(
            offset + len <= entry.total_len(),
            "{name}: range {offset}+{len} beyond entry len {}",
            entry.total_len()
        );
        let mut filled = 0u64;
        // walk extents in logical (payload) order, skipping to `offset`
        let mut pos = 0u64; // payload offset of the current extent
        for (ext_off, ext_len) in &entry.extents {
            let lo = offset.max(pos);
            let hi = (offset + len).min(pos + ext_len);
            if lo < hi {
                let at = (lo - offset) as usize;
                let n = (hi - lo) as usize;
                self.reader.read_exact_at(&mut dst[at..at + n],
                                          ext_off + (lo - pos))?;
                filled += hi - lo;
            }
            pos += ext_len;
            if pos >= offset + len {
                break;
            }
        }
        anyhow::ensure!(filled == len,
                        "{name}: short read {filled} of {len}");
        Ok(())
    }

    /// Reassemble every entry, in trailer order.
    pub fn read_all(&self) -> anyhow::Result<Vec<(String, Vec<u8>)>> {
        self.layout
            .entries
            .iter()
            .map(|e| Ok((e.name.clone(), self.read_extents(e)?)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    use super::*;
    use crate::config::EngineConfig;
    use crate::engine::{CheckpointEngine, DataStatesEngine};
    use crate::state::shard::FileKind;
    use crate::state::tensor::{DType, SimDeviceTensor, TensorShard};
    use crate::state::{PyObj, RankState, ShardFile, StateItem};
    use crate::util::TempDir;

    fn write_checkpoint(dir: &Path) -> (RankState, std::path::PathBuf) {
        let state = RankState {
            rank: 0,
            files: vec![ShardFile {
                name: "layer.pt".into(),
                kind: FileKind::ParamLayer,
                items: vec![
                    StateItem::Tensor(TensorShard::device(
                        "w", DType::U8, vec![8192],
                        SimDeviceTensor::new(
                            (0..8192u32).map(|i| (i % 251) as u8)
                                .collect()),
                    )),
                    StateItem::Object {
                        name: "meta".into(),
                        obj: PyObj::synthetic_metadata(2000, 3),
                    },
                ],
            }],
        };
        let mut eng =
            DataStatesEngine::new(EngineConfig::with_dir(dir)).unwrap();
        let ticket = eng.begin(0, &state).unwrap();
        ticket.wait_persisted().unwrap();
        (state, dir.join("v000000/layer.pt"))
    }

    #[test]
    fn chunk_stream_covers_every_entry_byte_exactly_once() {
        let dir = TempDir::new("restore-src").unwrap();
        let (_state, path) = write_checkpoint(dir.path());
        let mut src = ChunkSource::with_chunk_bytes(&path, 777).unwrap();
        // reassemble by label from the chunk stream
        let mut by_label: HashMap<String, Vec<(u64, Vec<u8>)>> =
            HashMap::new();
        let mut total = 0u64;
        while let Some(c) = src.next_chunk().unwrap() {
            total += c.data.len() as u64;
            by_label
                .entry(c.label.clone())
                .or_default()
                .push((c.offset, c.data.as_slice().to_vec()));
        }
        let expected: u64 = src
            .layout()
            .entries
            .iter()
            .map(|e| e.total_len())
            .sum();
        assert_eq!(total, expected);
        // streamed bytes equal the positioned-read reassembly
        for e in &src.layout().entries {
            let want = src.read_entry(&e.name).unwrap();
            let got: Vec<u8> = by_label[&e.name]
                .iter()
                .flat_map(|(_, b)| b.iter().copied())
                .collect();
            assert_eq!(got, want, "{}", e.name);
        }
    }

    #[test]
    fn read_entry_range_matches_full_read() {
        let dir = TempDir::new("restore-range").unwrap();
        let (_state, path) = write_checkpoint(dir.path());
        let src = ChunkSource::open(&path).unwrap();
        for e in &src.layout().entries {
            let full = src.read_entry(&e.name).unwrap();
            let n = full.len() as u64;
            // whole, prefix, suffix, interior, empty
            for (off, len) in
                [(0, n), (0, n / 2), (n / 2, n - n / 2),
                 (n / 3, n / 3), (n / 2, 0)]
            {
                let got =
                    src.read_entry_range(&e.name, off, len).unwrap();
                assert_eq!(got.as_slice(),
                           &full[off as usize..(off + len) as usize],
                           "{} [{off}+{len}]", e.name);
            }
            // beyond-EOF rejected
            assert!(src.read_entry_range(&e.name, n, 1).is_err());
        }
    }

    #[test]
    fn read_entry_matches_source_state() {
        let dir = TempDir::new("restore-src2").unwrap();
        let (state, path) = write_checkpoint(dir.path());
        let src = ChunkSource::open(&path).unwrap();
        let StateItem::Tensor(t) = &state.files[0].items[0] else {
            panic!()
        };
        let got = src.read_entry(&t.name).unwrap();
        let crate::state::TensorData::Device(d) = &t.data else {
            panic!()
        };
        let mut want = vec![0u8; d.size_bytes()];
        d.stage_into(&mut want).unwrap();
        assert_eq!(got, want);
        // objects deserialize through the streamed bytes too
        let meta = PyObj::from_bytes(&src.read_entry("meta").unwrap())
            .unwrap();
        assert_eq!(meta, PyObj::synthetic_metadata(2000, 3));
    }
}
