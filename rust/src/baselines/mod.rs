//! Faithful re-implementations of the checkpoint engines the paper
//! compares against (§VI-B), behind the same [`CheckpointEngine`] trait:
//!
//! - [`deepspeed_default::DeepSpeedDefaultEngine`] — `torch.save`-style:
//!   fully blocking, type-agnostic serialization of the entire object
//!   graph (tensors deep-copied through the serializer), single-threaded
//!   sequential writes.
//! - [`torchsnapshot::TorchSnapshotEngine`] — blocking snapshot
//!   (synchronous D2H into freshly-allocated buffers), then background
//!   multi-threaded flushing of *chunk files* (chunk-to-file mapping
//!   inflates file counts / metadata ops, §IV-D).
//! - [`datastates_old::DataStatesOldEngine`] — the authors' HPDC'24
//!   engine: lazy pinned-pool D2H overlapped with fwd/bwd (like the new
//!   engine) but metadata-first blocking serialization, per-file
//!   snapshot-then-flush (no chunk streaming), single writer thread.
//!
//! [`CheckpointEngine`]: crate::engine::CheckpointEngine

pub mod common;
pub mod datastates_old;
pub mod deepspeed_default;
pub mod torchsnapshot;

pub use datastates_old::DataStatesOldEngine;
pub use deepspeed_default::DeepSpeedDefaultEngine;
pub use torchsnapshot::TorchSnapshotEngine;

use crate::config::EngineConfig;
use crate::engine::{CheckpointEngine, DataStatesEngine};

/// Engine selector used by the CLI, benches, and examples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    DeepSpeedDefault,
    TorchSnapshot,
    DataStatesOld,
    DataStatesLlm,
}

impl EngineKind {
    pub fn all() -> [EngineKind; 4] {
        [
            EngineKind::DeepSpeedDefault,
            EngineKind::TorchSnapshot,
            EngineKind::DataStatesOld,
            EngineKind::DataStatesLlm,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::DeepSpeedDefault => "deepspeed-default",
            EngineKind::TorchSnapshot => "torchsnapshot",
            EngineKind::DataStatesOld => "datastates-old",
            EngineKind::DataStatesLlm => "datastates-llm",
        }
    }

    pub fn parse(s: &str) -> Option<EngineKind> {
        Self::all().into_iter().find(|k| k.label() == s)
    }

    /// Instantiate the engine.
    pub fn build(&self, cfg: EngineConfig)
        -> anyhow::Result<Box<dyn CheckpointEngine>> {
        Ok(match self {
            EngineKind::DeepSpeedDefault => {
                Box::new(DeepSpeedDefaultEngine::new(cfg)?)
            }
            EngineKind::TorchSnapshot => {
                Box::new(TorchSnapshotEngine::new(cfg)?)
            }
            EngineKind::DataStatesOld => {
                Box::new(DataStatesOldEngine::new(cfg)?)
            }
            EngineKind::DataStatesLlm => {
                Box::new(DataStatesEngine::new(cfg)?)
            }
        })
    }
}
