//! Flaky-tier acceptance tests (tier health & self-healing I/O):
//!
//! - seeded per-op transient fault rates up to 10% over varying tier
//!   stacks: every run either restores byte-identically to the serial
//!   oracle or fails with a clean error naming the tier;
//! - a persistently dead terminal tier trips the circuit breaker:
//!   later versions bypass the quarantined hop without wedging the
//!   drain queue, and once the tier heals, half-open probes reintegrate
//!   it and the skipped hops are resumed;
//! - the scrubber rebuilds a torn tier copy byte-identically from a
//!   surviving tier, and a second pass finds nothing left to repair.

use std::sync::Arc;

use datastates::config::EngineConfig;
use datastates::engine::{CheckpointEngine, DataStatesEngine};
use datastates::faults::FaultInjector;
use datastates::restore::{ReadEngine, ReadEngineConfig};
use datastates::state::tensor::{DType, SimDeviceTensor, TensorShard};
use datastates::state::{FileKind, PyObj, RankState, ShardFile, StateItem};
use datastates::storage::{TierKind, TierSpec};
use datastates::util::TempDir;

/// A rank state of `n_files` device-tensor files (multiple files per
/// version so breaker counters and drain hops see real file loops).
fn multi_file_state(n_files: usize, bytes: usize, seed: u64) -> RankState {
    let files = (0..n_files)
        .map(|i| {
            let payload: Vec<u8> = (0..bytes)
                .map(|j| {
                    ((j as u64)
                        .wrapping_mul(31)
                        .wrapping_add(seed ^ i as u64)
                        % 251) as u8
                })
                .collect();
            ShardFile {
                name: format!("layer{i}.pt"),
                kind: FileKind::ParamLayer,
                items: vec![
                    StateItem::Tensor(TensorShard::device(
                        "w", DType::U8, vec![bytes],
                        SimDeviceTensor::new(payload))),
                    StateItem::Object {
                        name: "meta".into(),
                        obj: PyObj::synthetic_metadata(300, seed ^ 0xAB),
                    },
                ],
            }
        })
        .collect();
    RankState { rank: 0, files }
}

/// Seeded fault sweep: rates up to 10% across two tier stacks. Each
/// cell must either (a) commit and restore byte-identically through
/// BOTH the parallel read engine and the serial oracle, or (b) fail
/// with an error that names the tier — never corrupt data, never hang.
#[test]
fn seeded_transient_faults_restore_byte_identical_or_fail_clean() {
    for seed in [1u64, 2, 3] {
        for rate in [0.02f64, 0.10] {
            let dir = TempDir::new("flaky-sweep").unwrap();
            let inj = Arc::new(FaultInjector::new(seed));
            inj.set_transient_rate(rate);
            let mut cfg = EngineConfig::with_dir(dir.path());
            cfg.chunk_bytes = 8 << 10;
            cfg.evict_fast_tier = false;
            cfg.retry_max = 3;
            cfg.faults = Some(inj.clone());
            // seeded stack variation: every other cell drains through
            // a zero-latency content-addressed remote tier too
            cfg.tiers = if seed % 2 == 0 {
                vec![TierSpec::host_cache(), TierSpec::local_fs()]
            } else {
                vec![
                    TierSpec::host_cache(),
                    TierSpec::local_fs(),
                    TierSpec::remote(0.0),
                ]
            };
            let mut eng = DataStatesEngine::new(cfg).unwrap();
            let state = multi_file_state(3, 96 << 10, seed);
            let committed = eng
                .begin(1, &state)
                .and_then(|t| t.wait_persisted().map(|_| ()));
            match committed {
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(msg.contains("tier"),
                            "seed {seed} rate {rate}: error must name \
                             the tier: {msg}");
                    continue;
                }
                Ok(()) => {}
            }
            let pipeline = eng.pipeline();
            // parallel engine vs serial oracle, both under live faults
            let rd = ReadEngine::new(ReadEngineConfig::default());
            match rd.read_version(pipeline.as_ref(), 1) {
                Ok(v) => datastates::restore::verify_files_against(
                             &v, &state)
                         .unwrap(),
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(msg.contains("tier"),
                            "seed {seed} rate {rate}: restore error \
                             must name the tier: {msg}");
                }
            }
            // the oracle runs clean: disarm and prove the bytes
            inj.set_transient_rate(0.0);
            let serial = pipeline.read_version_serial(1).unwrap();
            datastates::restore::verify_files_against(&serial, &state)
                .unwrap();
        }
    }
}

/// A dead terminal tier: the breaker quarantines it after consecutive
/// drain failures, later versions bypass the hop (landing persistence
/// resolves, the dead level degrades by name) without wedging the
/// queue; once the tier heals, half-open probes reintegrate it and the
/// skipped hops are resumed and readable byte-identically.
#[test]
fn quarantine_engages_bypasses_and_reintegrates() {
    let dir = TempDir::new("flaky-breaker").unwrap();
    let inj = Arc::new(FaultInjector::new(17));
    let mut cfg = EngineConfig::two_tier(dir.path());
    cfg.chunk_bytes = 8 << 10;
    cfg.evict_fast_tier = false;
    cfg.retry_max = 1;
    cfg.faults = Some(inj.clone());
    let mut eng = DataStatesEngine::new(cfg).unwrap();
    let pipeline = eng.pipeline();
    // every drain write to the terminal tier fails; the landing tier
    // stays healthy
    inj.set_transient_rate(1.0);
    inj.set_transient_tier(Some("local-fs"));

    let trip = datastates::storage::health::QUARANTINE_AFTER as u64;
    // pre-trip versions fail the historical way, naming the tier
    for v in 1..trip {
        let state = multi_file_state(2, 32 << 10, 100 + v);
        let e = eng
            .begin(v, &state)
            .and_then(|t| t.wait_persisted().map(|_| ()))
            .unwrap_err();
        assert!(e.to_string().contains("tier"), "v{v}: {e:#}");
    }
    // the trip and the version after it DEGRADE instead of failing
    for v in trip..=trip + 1 {
        let state = multi_file_state(2, 32 << 10, 100 + v);
        let t = eng.begin(v, &state).unwrap();
        t.wait_persisted().unwrap();
        let e = t.wait_durable(TierKind::LocalFs).unwrap_err();
        assert!(e.to_string().contains("quarantined"), "v{v}: {e:#}");
    }
    assert!(pipeline.health().quarantine_events_total() >= 1);
    assert!(pipeline.pending_hops() >= 1,
            "skipped hops must queue for recovery");
    // the drain queue must not wedge behind the quarantined tier
    for _ in 0..200 {
        if pipeline.drains_pending() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(pipeline.drains_pending(), 0, "drain queue wedged");

    // the tier heals: probes reintegrate, skipped hops resume
    inj.set_transient_rate(0.0);
    for v in trip + 2..=trip + 3 {
        // outlive the probe backoff so admit() draws a half-open probe
        std::thread::sleep(std::time::Duration::from_millis(25));
        let state = multi_file_state(2, 32 << 10, 100 + v);
        let t = eng.begin(v, &state).unwrap();
        t.wait_persisted().unwrap();
        let _ = t.wait_durable(TierKind::LocalFs); // settle the drain
    }
    pipeline.scrub_repair().unwrap();
    assert!(pipeline.health().reintegrations_total() >= 1,
            "the quarantined tier never reintegrated");
    assert_eq!(pipeline.pending_hops(), 0,
               "skipped hops were not resumed");
    // a version whose terminal hop was skipped is byte-identical now
    let v = pipeline.read_version(trip + 1).unwrap();
    datastates::restore::verify_files_against(
        &v, &multi_file_state(2, 32 << 10, 100 + trip + 1))
        .unwrap();
}

/// Scrub-and-repair: tear the terminal copy of a committed version on
/// disk; `scrub_repair` rebuilds it byte-identically from the intact
/// fast-tier copy, and a second pass verifies everything clean.
#[test]
fn scrubber_rebuilds_torn_tier_copy_byte_identically() {
    let dir = TempDir::new("flaky-scrub").unwrap();
    let mut cfg = EngineConfig::two_tier(dir.path());
    cfg.chunk_bytes = 8 << 10;
    cfg.evict_fast_tier = false; // keep the donor copy resident
    let mut eng = DataStatesEngine::new(cfg).unwrap();
    let state = multi_file_state(2, 48 << 10, 9);
    let t = eng.begin(1, &state).unwrap();
    t.wait_persisted().unwrap();
    t.wait_durable(TierKind::LocalFs).unwrap();
    let pipeline = eng.pipeline();

    // tear one terminal (local-fs) copy in place, manifest untouched
    let torn = dir.path().join("v000001/layer0.pt");
    assert!(torn.is_file(), "expected terminal copy at {torn:?}");
    datastates::faults::tear_file(&torn).unwrap();

    let rep = pipeline.scrub_repair().unwrap();
    assert!(rep.copies_repaired >= 1,
            "scrub must rebuild the torn copy: {rep:?}");
    assert!(rep.unrepairable.is_empty(), "{rep:?}");
    // the rebuilt copy is byte-identical through the whole version
    let v = pipeline.read_version(1).unwrap();
    datastates::restore::verify_files_against(&v, &state).unwrap();
    // and a second pass has nothing left to do
    let rep2 = pipeline.scrub_repair().unwrap();
    assert_eq!(rep2.copies_repaired, 0, "{rep2:?}");
    assert!(rep2.unrepairable.is_empty(), "{rep2:?}");
}
