//! The job-wide logical state index (§IV, Table I): logical tensor →
//! sorted physical extents with rank/file coordinates.
//!
//! The physical layout of a checkpoint — which rank wrote which slice
//! into which file — is an artifact of the topology it was written
//! under. The [`LogicalIndex`] inverts it: built from the per-rank
//! self-describing trailers (whose entries carry the partitioner's
//! [`LogicalRef`]s), it maps every logical tensor of the job to the
//! ordered physical extents covering it, validated on construction:
//!
//! - **full coverage** — the extents of each tensor tile `[0, len)`
//!   exactly, no gaps;
//! - **no overlap** — extents covering the same bytes are allowed only
//!   when they cover *identical* ranges (DP replicas, byte-identical by
//!   construction); those become restore-time alternates. Partial
//!   overlaps are layout bugs and rejected.
//!
//! The reshard planner (`restore::reshard`) maps a target topology onto
//! this index; [`flatten_states`] is the byte-level equality oracle the
//! round-trip tests use.

use std::collections::BTreeMap;

use crate::provider::layout::{EntryKind, FileLayout};
use crate::state::shard::{RankState, StateItem};
use crate::state::tensor::{DType, GlobalTensorId, TensorData};

/// One physical slice of a logical tensor: where its bytes live.
#[derive(Debug, Clone, PartialEq)]
pub struct PhysicalExtent {
    /// Source rank that wrote the slice.
    pub rank: usize,
    /// File name within the rank's version directory.
    pub file: String,
    /// Layout entry name within that file.
    pub entry: String,
    /// Logical byte range of the owning tensor this extent covers (the
    /// entry's payload bytes `[0, range.len())` map onto it 1:1).
    pub range: std::ops::Range<u64>,
}

impl PhysicalExtent {
    pub fn len(&self) -> u64 {
        self.range.end - self.range.start
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }
}

/// One logical tensor with its validated physical extent cover.
#[derive(Debug, Clone)]
pub struct LogicalTensor {
    pub id: GlobalTensorId,
    /// Total logical bytes.
    pub len: u64,
    /// Element dtype (when the writing entries recorded one).
    pub dtype: Option<DType>,
    /// Primary extents, sorted by `range.start` — an exact tiling of
    /// `[0, len)`.
    pub extents: Vec<PhysicalExtent>,
    /// Replica extents: alternates whose range is identical to some
    /// primary extent (DP replicas, byte-identical by construction).
    /// Restore may fall back to these when a primary copy is torn.
    pub replicas: Vec<PhysicalExtent>,
}

impl LogicalTensor {
    /// The reads materializing logical bytes `[range)` of this tensor:
    /// for each covering extent, the entry-relative offset/length plus
    /// the destination offset within the requested range, and any
    /// replica alternates for the same slice.
    pub fn reads_for(&self, range: std::ops::Range<u64>)
        -> anyhow::Result<Vec<SliceRead>> {
        anyhow::ensure!(range.end <= self.len,
                        "{}: range {:?} beyond len {}", self.id, range,
                        self.len);
        let mut out = Vec::new();
        for ext in &self.extents {
            let lo = ext.range.start.max(range.start);
            let hi = ext.range.end.min(range.end);
            if lo >= hi {
                continue;
            }
            let alternates = self
                .replicas
                .iter()
                .filter(|r| r.range == ext.range)
                .cloned()
                .collect();
            out.push(SliceRead {
                extent: ext.clone(),
                entry_offset: lo - ext.range.start,
                len: hi - lo,
                dst_offset: lo - range.start,
                alternates,
            });
        }
        Ok(out)
    }
}

/// One positioned read of a reshard plan: `len` bytes at
/// `entry_offset` of `extent`'s entry, landing at `dst_offset` of the
/// target slice. `alternates` are byte-identical replica extents to
/// fall back to when the primary copy cannot be read.
#[derive(Debug, Clone)]
pub struct SliceRead {
    pub extent: PhysicalExtent,
    pub entry_offset: u64,
    pub len: u64,
    pub dst_offset: u64,
    pub alternates: Vec<PhysicalExtent>,
}

/// The job-wide logical→physical index of one checkpoint version.
#[derive(Debug, Clone, Default)]
pub struct LogicalIndex {
    tensors: BTreeMap<String, LogicalTensor>,
}

/// Builder accumulating per-rank file layouts before validation.
#[derive(Debug, Default)]
pub struct LogicalIndexBuilder {
    raw: BTreeMap<String, (Option<DType>, Vec<PhysicalExtent>)>,
}

impl LogicalIndexBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record every logically-tagged entry of one file's trailer.
    /// Rejects entries whose logical range disagrees with their payload
    /// length — a corrupt trailer must not smuggle an absurd `len` into
    /// the index (the reshard executor sizes target buffers from it).
    pub fn add_layout(&mut self, rank: usize, layout: &FileLayout)
        -> anyhow::Result<()> {
        for entry in &layout.entries {
            let Some(l) = &entry.logical else { continue };
            anyhow::ensure!(
                l.len() == entry.total_len(),
                "{} {}: logical range {:?} ({} bytes) does not match \
                 payload length {}",
                layout.file_name, entry.name, l.range, l.len(),
                entry.total_len()
            );
            let dtype = match &entry.kind {
                EntryKind::Tensor { dtype, .. } => Some(*dtype),
                EntryKind::Object => None,
            };
            let slot = self
                .raw
                .entry(l.tensor.as_str().to_string())
                .or_insert_with(|| (dtype, Vec::new()));
            if slot.0.is_none() {
                slot.0 = dtype;
            }
            slot.1.push(PhysicalExtent {
                rank,
                file: layout.file_name.clone(),
                entry: entry.name.clone(),
                range: l.range.clone(),
            });
        }
        Ok(())
    }

    /// Record every logically-tagged shard of an in-memory rank state
    /// (write-side view; tests and pre-flight validation).
    pub fn add_state(&mut self, state: &RankState)
        -> anyhow::Result<()> {
        for file in &state.files {
            for item in &file.items {
                let StateItem::Tensor(t) = item else { continue };
                let Some(l) = &t.logical else { continue };
                anyhow::ensure!(
                    l.len() == t.size_bytes() as u64,
                    "{}: logical range {:?} does not match shard size {}",
                    t.name, l.range, t.size_bytes()
                );
                let slot = self
                    .raw
                    .entry(l.tensor.as_str().to_string())
                    .or_insert_with(|| (Some(t.dtype), Vec::new()));
                slot.1.push(PhysicalExtent {
                    rank: state.rank,
                    file: file.name.clone(),
                    entry: t.name.clone(),
                    range: l.range.clone(),
                });
            }
        }
        Ok(())
    }

    /// Validate coverage and overlap, producing the index.
    pub fn finish(self) -> anyhow::Result<LogicalIndex> {
        let mut tensors = BTreeMap::new();
        for (id, (dtype, mut extents)) in self.raw {
            extents.sort_by_key(|e| (e.range.start, e.range.end));
            let mut primary: Vec<PhysicalExtent> = Vec::new();
            let mut replicas = Vec::new();
            for ext in extents {
                anyhow::ensure!(!ext.is_empty(),
                                "{id}: empty extent from rank {} {}",
                                ext.rank, ext.entry);
                match primary.last() {
                    Some(prev) if ext.range == prev.range => {
                        // identical range: a DP replica, byte-identical
                        // by construction — keep as an alternate
                        replicas.push(ext);
                    }
                    Some(prev) if ext.range.start < prev.range.end => {
                        anyhow::bail!(
                            "{id}: partial overlap — rank {} {} covers \
                             {:?}, rank {} {} covers {:?}",
                            prev.rank, prev.entry, prev.range,
                            ext.rank, ext.entry, ext.range
                        );
                    }
                    Some(prev) if ext.range.start > prev.range.end => {
                        anyhow::bail!(
                            "{id}: gap — no bytes cover {:?}",
                            prev.range.end..ext.range.start
                        );
                    }
                    _ => primary.push(ext),
                }
            }
            let first = primary.first().expect("non-empty by entry");
            anyhow::ensure!(
                first.range.start == 0,
                "{id}: coverage starts at {} not 0", first.range.start
            );
            let len = primary.last().expect("non-empty").range.end;
            tensors.insert(
                id.clone(),
                LogicalTensor {
                    id: GlobalTensorId::new(id),
                    len,
                    dtype,
                    extents: primary,
                    replicas,
                },
            );
        }
        Ok(LogicalIndex { tensors })
    }
}

impl LogicalIndex {
    /// Build from per-rank trailer layouts.
    pub fn from_layouts<'a>(
        layouts: impl IntoIterator<Item = (usize, &'a FileLayout)>,
    ) -> anyhow::Result<LogicalIndex> {
        let mut b = LogicalIndexBuilder::new();
        for (rank, layout) in layouts {
            b.add_layout(rank, layout)?;
        }
        b.finish()
    }

    /// Build from in-memory rank states (write-side view).
    pub fn from_states(states: &[RankState])
        -> anyhow::Result<LogicalIndex> {
        let mut b = LogicalIndexBuilder::new();
        for s in states {
            b.add_state(s)?;
        }
        b.finish()
    }

    pub fn get(&self, id: &str) -> Option<&LogicalTensor> {
        self.tensors.get(id)
    }

    pub fn tensors(&self) -> impl Iterator<Item = &LogicalTensor> {
        self.tensors.values()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total logical bytes across all tensors.
    pub fn total_bytes(&self) -> u64 {
        self.tensors.values().map(|t| t.len).sum()
    }
}

/// Flatten the logically-tagged tensors of a set of rank states into
/// full logical-tensor payloads — the equality oracle for reshard
/// round-trips: a checkpoint written at topology A and one resharded to
/// topology B must flatten to identical maps. Replicated slices
/// (identical ranges) are verified byte-identical here.
pub fn flatten_states(states: &[RankState])
    -> anyhow::Result<BTreeMap<String, Vec<u8>>> {
    let mut slices: BTreeMap<String, Vec<(u64, u64, Vec<u8>)>> =
        BTreeMap::new();
    for state in states {
        for file in &state.files {
            for item in &file.items {
                let StateItem::Tensor(t) = item else { continue };
                let Some(l) = &t.logical else { continue };
                let bytes: Vec<u8> = match &t.data {
                    TensorData::Host(b) => b.as_ref().clone(),
                    TensorData::Device(d) => {
                        let mut v = vec![0u8; d.size_bytes()];
                        d.stage_into(&mut v)?;
                        v
                    }
                };
                anyhow::ensure!(
                    bytes.len() as u64 == l.len(),
                    "{}: {} payload bytes but logical range {:?}",
                    t.name, bytes.len(), l.range
                );
                slices
                    .entry(l.tensor.as_str().to_string())
                    .or_default()
                    .push((l.range.start, l.range.end, bytes));
            }
        }
    }
    let mut out = BTreeMap::new();
    for (id, mut parts) in slices {
        parts.sort_by_key(|(s, e, _)| (*s, *e));
        let mut flat: Vec<u8> = Vec::new();
        let mut prev: Option<(u64, u64, &[u8])> = None;
        for (s, e, bytes) in &parts {
            if let Some((ps, pe, pb)) = prev {
                if (*s, *e) == (ps, pe) {
                    anyhow::ensure!(
                        bytes.as_slice() == pb,
                        "{id}: replicas of {:?} differ", ps..pe
                    );
                    continue;
                }
                anyhow::ensure!(
                    *s == pe,
                    "{id}: gap/overlap between {:?} and {:?}",
                    ps..pe, *s..*e
                );
            } else {
                anyhow::ensure!(*s == 0,
                                "{id}: coverage starts at {s} not 0");
            }
            flat.extend_from_slice(bytes);
            prev = Some((*s, *e, bytes.as_slice()));
        }
        out.insert(id, flat);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provider::layout::LayoutEntry;
    use crate::state::tensor::LogicalRef;

    fn entry(name: &str, tensor: &str, range: std::ops::Range<u64>)
        -> LayoutEntry {
        LayoutEntry {
            name: name.into(),
            kind: EntryKind::Tensor { dtype: DType::U8, shape: vec![1] },
            extents: vec![(0, range.end - range.start)],
            logical: Some(LogicalRef::new(tensor, range)),
        }
    }

    fn layout(file: &str, entries: Vec<LayoutEntry>) -> FileLayout {
        FileLayout { file_name: file.into(), fixed_region: 0, entries }
    }

    #[test]
    fn builds_and_validates_exact_tiling() {
        let l0 = layout("a.pt", vec![entry("t::0", "w", 0..10)]);
        let l1 = layout("b.pt", vec![entry("t::1", "w", 10..30)]);
        let idx =
            LogicalIndex::from_layouts([(0, &l0), (1, &l1)]).unwrap();
        let t = idx.get("w").unwrap();
        assert_eq!(t.len, 30);
        assert_eq!(t.extents.len(), 2);
        assert_eq!(t.dtype, Some(DType::U8));
        assert_eq!(idx.total_bytes(), 30);
        // sub-range read plan spans the extent boundary
        let reads = t.reads_for(5..15).unwrap();
        assert_eq!(reads.len(), 2);
        assert_eq!((reads[0].entry_offset, reads[0].len,
                    reads[0].dst_offset), (5, 5, 0));
        assert_eq!((reads[1].entry_offset, reads[1].len,
                    reads[1].dst_offset), (0, 5, 5));
    }

    #[test]
    fn identical_ranges_become_replicas() {
        let l0 = layout("a.pt", vec![entry("t::0", "w", 0..10)]);
        let l1 = layout("b.pt", vec![entry("t::1", "w", 0..10)]);
        let idx =
            LogicalIndex::from_layouts([(0, &l0), (1, &l1)]).unwrap();
        let t = idx.get("w").unwrap();
        assert_eq!(t.extents.len(), 1);
        assert_eq!(t.replicas.len(), 1);
        let reads = t.reads_for(0..10).unwrap();
        assert_eq!(reads[0].alternates.len(), 1);
    }

    #[test]
    fn gaps_and_partial_overlaps_rejected() {
        let gap = LogicalIndex::from_layouts([
            (0, &layout("a.pt", vec![entry("e", "w", 0..10)])),
            (1, &layout("b.pt", vec![entry("e", "w", 12..20)])),
        ]);
        assert!(gap.unwrap_err().to_string().contains("gap"));
        let ovl = LogicalIndex::from_layouts([
            (0, &layout("a.pt", vec![entry("e", "w", 0..10)])),
            (1, &layout("b.pt", vec![entry("e", "w", 5..20)])),
        ]);
        assert!(ovl.unwrap_err().to_string().contains("overlap"));
        let off = LogicalIndex::from_layouts([(
            0,
            &layout("a.pt", vec![entry("e", "w", 5..10)]),
        )]);
        assert!(off.unwrap_err().to_string().contains("starts at 5"));
    }

    #[test]
    fn logical_range_must_match_payload_length() {
        // a corrupt trailer claiming a huge logical range is rejected
        // at index build, before any buffer is sized from it
        let mut e = entry("e", "w", 0..10);
        e.logical = Some(LogicalRef::new("w", 0..u64::MAX / 2));
        let bad = LogicalIndex::from_layouts([(
            0,
            &layout("a.pt", vec![e]),
        )]);
        assert!(bad.unwrap_err().to_string()
            .contains("does not match payload length"));
    }

    #[test]
    fn flatten_states_assembles_and_checks_replicas() {
        use crate::state::shard::{FileKind, ShardFile};
        use crate::state::tensor::TensorShard;
        let shard = |name: &str, bytes: Vec<u8>,
                     range: std::ops::Range<u64>| {
            StateItem::Tensor(
                TensorShard::host(name, DType::U8,
                                  vec![bytes.len()], bytes)
                    .with_logical(Some(LogicalRef::new("w", range))),
            )
        };
        let mk = |rank, items| RankState {
            rank,
            files: vec![ShardFile {
                name: "f.pt".into(),
                kind: FileKind::ParamLayer,
                items,
            }],
        };
        let states = vec![
            mk(0, vec![shard("a", vec![1, 2], 0..2)]),
            mk(1, vec![shard("b", vec![3, 4, 5], 2..5)]),
            mk(2, vec![shard("c", vec![1, 2], 0..2)]), // replica of a
        ];
        let flat = flatten_states(&states).unwrap();
        assert_eq!(flat["w"], vec![1, 2, 3, 4, 5]);
        // a differing replica fails
        let bad = vec![
            mk(0, vec![shard("a", vec![1, 2], 0..2)]),
            mk(1, vec![shard("c", vec![9, 9], 0..2)]),
        ];
        assert!(flatten_states(&bad).unwrap_err().to_string()
            .contains("replicas"));
    }
}
