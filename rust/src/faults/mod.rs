//! Deterministic fault injection (ROADMAP open item 3).
//!
//! TierCheck's argument — fast-tier checkpoints are worthless if they
//! die with the node — only holds weight if the recovery paths are
//! *proven*: this module provides the seeded kill points the
//! `figures faults` matrix drives through the real write/drain/
//! replicate/restore code, so every cell of
//! (kill point × replication on/off × torn/lost tier) either recovers
//! the last committed version byte-identically or fails with a clean
//! named error.
//!
//! Design: a [`FaultInjector`] is armed with one [`KillPoint`] and a
//! deterministic trigger count N; the N-th crossing of that point
//! *fires* — the hook site then simulates the failure (abort the
//! capture, tear the half-drained file, drop the replica push, fail
//! the tier probe). Crossings and firings are counted so the harness
//! can assert the injection actually happened. Injectors are plumbed
//! through `EngineConfig::faults` into the tier pipeline; production
//! paths carry `None` and pay one `Option` check per hook.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Where in the checkpoint lifecycle the failure strikes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KillPoint {
    /// While the version is still landing on the fastest tier: the
    /// landing-tier file create aborts, leaving a partial version that
    /// must never become committed.
    MidCapture,
    /// During a tier-to-tier drain copy: the destination file is torn
    /// mid-copy (short write, no finalize), so the deeper tier holds a
    /// corrupt copy the restore path must fall through.
    MidDrain,
    /// During a peer replica push: the peer copy is dropped mid-file,
    /// so replica durability must NOT be reported for the version.
    MidReplicate,
    /// During restore's nearest-tier resolution: the first tier probe
    /// fails once, exercising the torn-copy fall-through.
    MidRestore,
}

impl KillPoint {
    pub fn label(&self) -> &'static str {
        match self {
            KillPoint::MidCapture => "mid-capture",
            KillPoint::MidDrain => "mid-drain",
            KillPoint::MidReplicate => "mid-replicate",
            KillPoint::MidRestore => "mid-restore",
        }
    }

    /// Parse a CLI kill-point name.
    pub fn parse(s: &str) -> Option<KillPoint> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mid-capture" | "capture" => Some(KillPoint::MidCapture),
            "mid-drain" | "drain" => Some(KillPoint::MidDrain),
            "mid-replicate" | "replicate" | "mid-replica" => {
                Some(KillPoint::MidReplicate)
            }
            "mid-restore" | "restore" => Some(KillPoint::MidRestore),
            _ => None,
        }
    }

    /// The full matrix, in lifecycle order.
    pub fn all() -> [KillPoint; 4] {
        [
            KillPoint::MidCapture,
            KillPoint::MidDrain,
            KillPoint::MidReplicate,
            KillPoint::MidRestore,
        ]
    }
}

#[derive(Debug, Default)]
struct Armed {
    point: Option<KillPoint>,
    /// Fire on the N-th crossing (1 = first). Derived from the seed so
    /// two runs with one seed kill the same file of the same version.
    trigger: u64,
}

/// Seeded, deterministic kill-point injector.
///
/// One injector is armed for at most one kill point at a time; hook
/// sites call [`FaultInjector::check`] with their point and fail when
/// it returns `true`. All counters are monotonic across re-arms so a
/// harness can assert per-cell firing counts.
#[derive(Debug, Default)]
pub struct FaultInjector {
    seed: u64,
    armed: Mutex<Armed>,
    crossings: AtomicU64,
    fired: AtomicU64,
}

impl FaultInjector {
    /// A new, disarmed injector. The seed perturbs which crossing of
    /// the armed point fires (`1 + seed % 2`: first or second), keeping
    /// runs deterministic per seed while varying the torn file.
    pub fn new(seed: u64) -> FaultInjector {
        FaultInjector { seed, ..FaultInjector::default() }
    }

    /// Arm the injector for `point`; the N-th crossing fires, where N
    /// is derived from the seed. Resets the crossing counter for the
    /// new point but keeps the lifetime `fired` total.
    pub fn arm(&self, point: KillPoint) {
        let mut a = self.armed.lock().unwrap();
        a.point = Some(point);
        a.trigger = 1 + self.seed % 2;
        self.crossings.store(0, Ordering::SeqCst);
    }

    /// Disarm without firing.
    pub fn disarm(&self) {
        self.armed.lock().unwrap().point = None;
    }

    /// Hook-site probe: returns `true` exactly once per arm — on the
    /// seeded N-th crossing of the armed point — after which the
    /// injector disarms itself (so recovery retries run clean).
    pub fn check(&self, point: KillPoint) -> bool {
        let mut a = self.armed.lock().unwrap();
        if a.point != Some(point) {
            return false;
        }
        let n = self.crossings.fetch_add(1, Ordering::SeqCst) + 1;
        if n >= a.trigger {
            a.point = None;
            self.fired.fetch_add(1, Ordering::SeqCst);
            true
        } else {
            false
        }
    }

    /// Lifetime count of injected failures.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }

    /// Currently armed kill point, if any.
    pub fn armed(&self) -> Option<KillPoint> {
        self.armed.lock().unwrap().point
    }
}

/// Tear a file in place on the real filesystem: truncate it to half
/// its length (at least 1 byte short) WITHOUT touching any manifest —
/// the torn-copy shape a crash mid-write leaves behind. Returns the
/// bytes removed.
pub fn tear_file(path: &std::path::Path) -> crate::Result<u64> {
    use anyhow::Context;
    let len = std::fs::metadata(path)
        .with_context(|| format!("tear_file stat {path:?}"))?
        .len();
    let keep = (len / 2).min(len.saturating_sub(1));
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("tear_file open {path:?}"))?;
    f.set_len(keep)
        .with_context(|| format!("tear_file truncate {path:?}"))?;
    Ok(len - keep)
}

/// Whole-node loss: delete a rank's ENTIRE checkpoint tree (fast tier
/// + local FS + any deeper tier rooted under its directory), leaving
/// only whatever peers replicated. Returns whether anything existed.
pub fn lose_rank_dir(dir: &std::path::Path) -> crate::Result<bool> {
    use anyhow::Context;
    if !dir.exists() {
        return Ok(false);
    }
    std::fs::remove_dir_all(dir)
        .with_context(|| format!("lose_rank_dir {dir:?}"))?;
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_once_per_arm() {
        let inj = FaultInjector::new(0); // trigger = 1: first crossing
        inj.arm(KillPoint::MidDrain);
        assert!(!inj.check(KillPoint::MidCapture)); // wrong point
        assert!(inj.check(KillPoint::MidDrain));
        assert!(!inj.check(KillPoint::MidDrain)); // self-disarmed
        assert_eq!(inj.fired(), 1);
    }

    #[test]
    fn seed_selects_crossing_deterministically() {
        let inj = FaultInjector::new(1); // trigger = 2: second crossing
        inj.arm(KillPoint::MidReplicate);
        assert!(!inj.check(KillPoint::MidReplicate));
        assert!(inj.check(KillPoint::MidReplicate));
        assert_eq!(inj.fired(), 1);
        // identical seed ⇒ identical firing pattern
        let inj2 = FaultInjector::new(1);
        inj2.arm(KillPoint::MidReplicate);
        assert!(!inj2.check(KillPoint::MidReplicate));
        assert!(inj2.check(KillPoint::MidReplicate));
    }

    #[test]
    fn disarm_prevents_firing() {
        let inj = FaultInjector::new(0);
        inj.arm(KillPoint::MidRestore);
        inj.disarm();
        assert!(!inj.check(KillPoint::MidRestore));
        assert_eq!(inj.fired(), 0);
    }

    #[test]
    fn kill_point_labels_roundtrip() {
        for p in KillPoint::all() {
            assert_eq!(KillPoint::parse(p.label()), Some(p));
        }
        assert_eq!(KillPoint::parse("nope"), None);
    }

    #[test]
    fn tear_file_shortens_without_deleting() {
        let dir = crate::util::tempdir::TempDir::new("ds-faults").unwrap();
        let p = dir.path().join("shard.bin");
        std::fs::write(&p, vec![7u8; 1000]).unwrap();
        let removed = tear_file(&p).unwrap();
        assert_eq!(removed, 500);
        assert_eq!(std::fs::metadata(&p).unwrap().len(), 500);
    }

    #[test]
    fn lose_rank_dir_removes_everything() {
        let dir = crate::util::tempdir::TempDir::new("ds-faults").unwrap();
        let rank = dir.path().join("rank000");
        std::fs::create_dir_all(rank.join("v000001")).unwrap();
        std::fs::write(rank.join("v000001/a.bin"), b"x").unwrap();
        assert!(lose_rank_dir(&rank).unwrap());
        assert!(!rank.exists());
        assert!(!lose_rank_dir(&rank).unwrap()); // idempotent
    }
}
