//! The tier pipeline: land fast, drain deep, restore from the nearest
//! copy.
//!
//! A [`TierPipeline`] owns an ordered stack of [`Backend`]s, fastest
//! first; the last is the **terminal** (most durable) tier. The engine's
//! pump lands checkpoint chunks on the landing (fastest) tier exactly as
//! it used to land them on a flat filesystem; once every file of a
//! version is finalized there, the pump submits a [`VersionDrainJob`]
//! and the pipeline's drain worker copies the version tier-to-tier in
//! the background — event-driven off its job channel, no sleep-polling —
//! marking the checkpoint session durable at each tier as the copy
//! lands (`CheckpointTicket::wait_durable`), evicting host-cache copies
//! once drained, and recording residency in the per-rank cross-tier
//! [`Manifest`].
//!
//! Restore resolves the other way: [`TierPipeline::read_version`] reads
//! each file from the NEAREST (fastest) tier holding it and falls
//! through to deeper tiers on missing or torn copies;
//! [`TierPipeline::restore_newest`] walks versions newest-first until
//! one restores completely.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::content::{RemoteStore, DEFAULT_CONTENT_CHUNK_BYTES};
use super::health::{fnv1a, Admission, HealthRegistry, RetryPolicy};
use super::{Backend, BackendFile, HostCache, LocalFs, ReadAt,
            ReplicaSpec, Throttle, TierKind, TierSpec, UringStats};
use crate::engine::ticket::CkptSession;
use crate::faults::{FaultInjector, KillPoint};
use crate::metrics::{Tier, Timeline};
use crate::restore::RestoredFile;
use crate::util::channel::{Receiver, Sender};

/// Tier-relative name of the persisted manifest on the terminal tier.
const MANIFEST_FILE: &str = "MANIFEST";

/// A restored checkpoint version: every file of the version, each read
/// from its nearest readable tier.
pub type RestoredVersion =
    std::collections::HashMap<String, RestoredFile>;

/// One finalized checkpoint version handed to the drain worker by the
/// engine pump (landing-tier copy complete).
pub struct VersionDrainJob {
    pub session: Arc<CkptSession>,
    /// Wall-clock origin of the checkpoint request, for per-tier
    /// durability timing.
    pub requested: Instant,
    /// Version directory, tier-relative (`"v000042"`).
    pub dir: String,
    /// File names within the version directory.
    pub files: Vec<String>,
    /// Signalled after evictions and when the drain finishes, so a pump
    /// parked on admission backpressure wakes to re-check capacity.
    pub notify: Option<Arc<crate::provider::Notifier>>,
}

/// Per-version residency: which tiers hold a complete copy.
#[derive(Debug, Clone)]
struct VersionRecord {
    files: Vec<String>,
    /// `complete[i]` — tier `i` holds every file of this version.
    complete: Vec<bool>,
}

/// The per-rank cross-tier manifest: for every checkpoint version, the
/// file set and the tiers holding a complete copy. Persisted as a small
/// text file on the terminal tier (rewritten whole on update) so
/// restarts resolve residency without scanning.
pub struct Manifest {
    /// The current pipeline's tier kinds, fastest first — residency
    /// columns are matched by KIND on load, so a manifest written under
    /// a different tier config cannot misattribute residency.
    kinds: Vec<TierKind>,
    records: Mutex<BTreeMap<u64, VersionRecord>>,
}

impl Manifest {
    fn new(kinds: Vec<TierKind>) -> Manifest {
        Manifest { kinds, records: Mutex::new(BTreeMap::new()) }
    }

    /// Load the persisted manifest from the terminal tier (empty when
    /// absent or unparsable — residency then falls back to tier scans).
    fn load(terminal: &dyn Backend, kinds: Vec<TierKind>) -> Manifest {
        let m = Manifest::new(kinds);
        if let Ok(reader) = terminal.open(MANIFEST_FILE) {
            if let Ok(len) = reader.len() {
                let mut buf = vec![0u8; len as usize];
                if reader.read_exact_at(&mut buf, 0).is_ok() {
                    if let Ok(text) = String::from_utf8(buf) {
                        m.parse_into(&text);
                    }
                }
            }
        }
        m
    }

    fn parse_into(&self, text: &str) {
        // The `tiers` header names the kind of each recorded column;
        // map columns onto the current stack by kind (each current tier
        // claimed once, nearest first). Without a header (legacy),
        // columns map positionally. Unmappable columns are dropped —
        // restore falls back to per-tier `exists()` scans anyway.
        let mut col_map: Option<Vec<Option<usize>>> = None;
        for line in text.lines() {
            if let Some(labels) = line.strip_prefix("tiers\t") {
                let mut used = vec![false; self.kinds.len()];
                col_map = Some(
                    labels
                        .split(',')
                        .map(|label| {
                            let hit = self.kinds.iter().enumerate().find(
                                |(i, k)| {
                                    !used[*i] && k.label() == label.trim()
                                },
                            );
                            hit.map(|(i, _)| {
                                used[i] = true;
                                i
                            })
                        })
                        .collect(),
                );
            }
        }
        let mut records = self.records.lock().unwrap();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty()
                || line.starts_with('#')
                || line.starts_with("tiers\t")
            {
                continue;
            }
            let mut parts = line.split('\t');
            let (Some(v), Some(bits), Some(files)) =
                (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let Ok(version) = v.parse::<u64>() else { continue };
            let mut complete = vec![false; self.kinds.len()];
            for (i, c) in bits.chars().enumerate() {
                if c != '1' {
                    continue;
                }
                let target = match &col_map {
                    Some(m) => m.get(i).copied().flatten(),
                    None => (i < complete.len()).then_some(i),
                };
                if let Some(t) = target {
                    complete[t] = true;
                }
            }
            records.insert(
                version,
                VersionRecord {
                    files: files
                        .split(',')
                        .filter(|f| !f.is_empty())
                        .map(|f| f.to_string())
                        .collect(),
                    complete,
                },
            );
        }
    }

    fn encode(&self) -> String {
        let records = self.records.lock().unwrap();
        let mut out =
            String::from("# datastates cross-tier manifest v1\n");
        let labels: Vec<&str> =
            self.kinds.iter().map(|k| k.label()).collect();
        out.push_str(&format!("tiers\t{}\n", labels.join(",")));
        for (version, rec) in records.iter() {
            let bits: String = rec
                .complete
                .iter()
                .map(|&c| if c { '1' } else { '0' })
                .collect();
            out.push_str(&format!("{version}\t{bits}\t{}\n",
                                  rec.files.join(",")));
        }
        out
    }

    /// Mark tier `tier` (in)complete for `version`, creating the record
    /// if needed. A non-empty `files` set that DIFFERS from the
    /// recorded one means the version was rewritten (e.g. re-taken
    /// after a restart with a different shard layout): the stale record
    /// is reset so old completeness flags cannot vouch for files that
    /// no longer exist.
    fn set(&self, version: u64, files: &[String], tier: usize,
           complete: bool) {
        let mut records = self.records.lock().unwrap();
        let rec = records.entry(version).or_insert_with(|| VersionRecord {
            files: files.to_vec(),
            complete: vec![false; self.kinds.len()],
        });
        if !files.is_empty() && rec.files.as_slice() != files {
            rec.files = files.to_vec();
            rec.complete.iter_mut().for_each(|c| *c = false);
        }
        if tier < rec.complete.len() {
            rec.complete[tier] = complete;
        }
    }

    /// Tier indices holding a complete copy of `version`, nearest first.
    pub fn lives_on(&self, version: u64) -> Vec<usize> {
        self.records
            .lock()
            .unwrap()
            .get(&version)
            .map(|r| {
                r.complete
                    .iter()
                    .enumerate()
                    .filter(|(_, &c)| c)
                    .map(|(i, _)| i)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Recorded file set of `version`.
    pub fn files(&self, version: u64) -> Option<Vec<String>> {
        self.records
            .lock()
            .unwrap()
            .get(&version)
            .map(|r| r.files.clone())
    }

    /// All recorded versions, ascending.
    pub fn versions(&self) -> Vec<u64> {
        self.records.lock().unwrap().keys().copied().collect()
    }
}

/// State shared between the pipeline handle, its drain worker, and any
/// restore-engine pass sources holding the tier stack alive (the worker
/// and the sources must not hold the handle itself, or drop/join would
/// cycle). Crate-visible so `restore::engine::Source` can own the tier
/// stack by `Arc` — gather runs then carry no pipeline borrows and can
/// flow to the persistent serving worker threads.
pub(crate) struct PipelineShared {
    tiers: Vec<Arc<dyn Backend>>,
    manifest: Manifest,
    timeline: Arc<Timeline>,
    /// Evict host-cache copies once drained to the next tier.
    evict_fast: bool,
    /// Copy granularity for tier-to-tier drains.
    chunk_bytes: usize,
    /// Versions submitted to the drain worker and not yet finished
    /// (admission backpressure uses this to tell "space will free soon"
    /// from "nothing left to evict").
    drains_pending: std::sync::atomic::AtomicUsize,
    /// Restore-engine knobs used by this pipeline's read paths
    /// (`read_version` / `restore_newest`). Defaults apply until the
    /// owning engine installs its `EngineConfig`-derived settings
    /// (`restore_lanes`, `reader_threads`, coalesce/pool sizing).
    read_cfg: Mutex<crate::restore::ReadEngineConfig>,
    /// Peer-replication targets (one backend per peer directory) and
    /// the shared replication-bandwidth throttle. Empty = replication
    /// off. Installed by `set_replicas` before the first drain.
    replicas: Mutex<ReplicaTargets>,
    /// Deterministic kill points for the `figures faults` matrix;
    /// `None` (production) costs one `Option` check per hook.
    faults: Mutex<Option<Arc<FaultInjector>>>,
    /// Tier health (ISSUE 10): one circuit breaker per tier plus the
    /// pipeline's transient-retry policy. Every I/O path records its
    /// outcomes here; the drain worker consults it to SKIP quarantined
    /// tiers instead of wedging the queue behind them.
    health: HealthRegistry,
    /// Drain hops skipped because their destination tier was
    /// quarantined; the drain worker (and the scrubber) retries them
    /// once the tier's half-open probes readmit it.
    pending_hops: Mutex<Vec<PendingHop>>,
    /// Run the scrubber on the drain worker after each drained version
    /// (the `--scrub` knob): re-verify that version's copies and
    /// rebuild torn ones from a surviving tier or peer.
    scrub: std::sync::atomic::AtomicBool,
}

/// A skipped drain hop awaiting the destination tier's recovery.
struct PendingHop {
    version: u64,
    dir: String,
    files: Vec<String>,
    /// Destination tier index of the skipped hop.
    to: usize,
}

#[derive(Default)]
struct ReplicaTargets {
    peers: Vec<Arc<dyn Backend>>,
    throttle: Option<Arc<Throttle>>,
}

impl PipelineShared {
    fn terminal(&self) -> &Arc<dyn Backend> {
        self.tiers.last().expect("pipeline has at least one tier")
    }

    /// The tier stack, fastest first (restore-engine source resolution).
    pub(crate) fn tier_stack(&self) -> &[Arc<dyn Backend>] {
        &self.tiers
    }

    /// Ring attribution summed across every tier running an io_uring
    /// (`None` when no tier does).
    pub(crate) fn uring_stats_agg(&self) -> Option<UringStats> {
        let mut agg: Option<UringStats> = None;
        for t in &self.tiers {
            if let Some(s) = t.uring_stats() {
                agg.get_or_insert_with(UringStats::default).merge(&s);
            }
        }
        agg
    }

    /// Persist the manifest on the terminal tier, publishing through a
    /// temp file + rename so a crash mid-rewrite can never leave a torn
    /// manifest. Failures are reported but non-fatal: the checkpoint
    /// payload is already durable, and restore falls back to tier scans
    /// without a manifest.
    fn persist_manifest(&self) {
        let text = self.manifest.encode();
        let tmp = format!("{MANIFEST_FILE}.tmp");
        let res = self
            .terminal()
            .create(&tmp)
            .and_then(|f| {
                f.write_at(0, text.as_bytes())?;
                f.finalize()
            })
            .and_then(|()| self.terminal().rename(&tmp, MANIFEST_FILE));
        if let Err(e) = res {
            eprintln!("[storage] manifest persist failed: {e:#}");
        }
    }

    /// The armed fault injector, if any (cheap clone of the `Arc`).
    fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        self.faults.lock().unwrap().clone()
    }

    /// Copy one file from tier `from` to tier `to` (normally adjacent,
    /// but a quarantined middle tier makes the drain hop over it). One
    /// call is ONE attempt — the caller wraps it in the retry policy.
    fn drain_file(&self, from: usize, to: usize, rel: &str,
                  session: Option<&CkptSession>) -> anyhow::Result<u64> {
        let fault = self.fault_injector();
        let dst_label = self.tiers[to].kind().label();
        if let Some(inj) = &fault {
            // slow-tier mode: the whole-file copy pays the injected
            // stall once (a stalled-but-healthy destination device)
            let d = inj.slow_delay_s(dst_label);
            if d > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(d));
            }
        }
        let src = self.tiers[from].open(rel)?;
        let len = src.len()?;
        let dst = self.tiers[to].create(rel)?;
        let start = self.timeline.now_s();
        // chunk_bytes is clamped >= 1 at construction
        let mut buf = vec![0u8; self.chunk_bytes.min(len.max(1) as usize)];
        let mut off = 0u64;
        while off < len {
            let take = ((len - off) as usize).min(buf.len());
            src.read_exact_at(&mut buf[..take], off)?;
            if let Some(inj) = &fault {
                if inj.check(KillPoint::MidDrain) {
                    // crash mid-copy: a SHORT write lands and the file
                    // is never finalized — the torn-copy shape restore's
                    // fall-through must survive
                    dst.write_at(off, &buf[..take / 2])?;
                    anyhow::bail!(
                        "fault injected: mid-drain (torn {rel} on \
                         {dst_label})"
                    );
                }
                if let Some(e) =
                    inj.transient_error("drain write", dst_label)
                {
                    return Err(e.context(format!("drain of {rel}")));
                }
            }
            dst.write_at(off, &buf[..take])?;
            off += take as u64;
        }
        dst.finalize()?;
        // content-addressed tiers report how much of the file actually
        // moved — the incremental-checkpoint attribution
        if let Some(st) = dst.upload_stats() {
            if let Some(s) = session {
                s.add_content(st.chunks_total, st.chunks_uploaded,
                              st.dedup_bytes_skipped);
            }
        }
        self.timeline
            .record(Tier::Drain, rel, len, start, self.timeline.now_s());
        if let Some(s) = session {
            s.progress_counters().add_drained(len);
        }
        Ok(len)
    }

    /// Drain one file under the pipeline's retry policy, recording the
    /// outcome on the destination tier's circuit breaker. Transient
    /// errors (EINTR/EAGAIN-shaped, injected transients) retry in
    /// place; permanent errors surface immediately.
    fn drain_file_retry(&self, from: usize, to: usize, rel: &str,
                        session: Option<&CkptSession>)
        -> anyhow::Result<u64> {
        let policy = self.health.policy();
        let breaker = self.health.tier(to);
        let t0 = Instant::now();
        let (res, _retries) = policy.run(fnv1a(rel.as_bytes()), || {
            self.drain_file(from, to, rel, session)
        });
        match &res {
            Ok(_) => breaker.record_ok(t0.elapsed().as_secs_f64()),
            Err(_) => breaker.record_err(),
        }
        res
    }

    /// Push one file to a peer replica target, charging the shared
    /// replication throttle chunk by chunk.
    fn replicate_file(&self, peer: &dyn Backend, rel: &str,
                      throttle: Option<&Throttle>)
        -> anyhow::Result<u64> {
        // replicate runs BEFORE the first drain hop (and before any
        // eviction), so the nearest tier still holds the file; taking
        // the first holder also serves replicate-only single-tier jobs
        let src_tier = self
            .tiers
            .iter()
            .find(|t| t.exists(rel))
            .ok_or_else(|| {
                anyhow::anyhow!("{rel}: no local tier holds a copy to \
                                 replicate")
            })?;
        let src = src_tier.open(rel)?;
        let len = src.len()?;
        let dst = peer.create(rel)?;
        let start = self.timeline.now_s();
        let fault = self.fault_injector();
        let mut buf = vec![0u8; self.chunk_bytes.min(len.max(1) as usize)];
        let mut off = 0u64;
        while off < len {
            let take = ((len - off) as usize).min(buf.len());
            src.read_exact_at(&mut buf[..take], off)?;
            if let Some(t) = throttle {
                t.acquire(take as u64);
            }
            if let Some(inj) = &fault {
                if inj.check(KillPoint::MidReplicate) {
                    // the peer keeps a torn, never-finalized copy
                    dst.write_at(off, &buf[..take / 2])?;
                    anyhow::bail!(
                        "fault injected: mid-replicate (torn {rel} on \
                         peer)"
                    );
                }
                if let Some(e) =
                    inj.transient_error("replica push", "peer")
                {
                    return Err(e.context(format!("replica of {rel}")));
                }
            }
            dst.write_at(off, &buf[..take])?;
            off += take as u64;
        }
        dst.finalize()?;
        self.timeline
            .record(Tier::Drain, rel, len, start, self.timeline.now_s());
        Ok(len)
    }

    /// Mirror one finalized version to every configured peer. Runs
    /// before the drain hops (the landing copy is still resident), so
    /// replica durability can resolve without waiting for deep tiers.
    /// A failed push fails only the version's REPLICA durability level
    /// — local persistence is unaffected.
    fn replicate_version(&self, job: &VersionDrainJob) {
        let (peers, throttle) = {
            let st = self.replicas.lock().unwrap();
            (st.peers.clone(), st.throttle.clone())
        };
        if peers.is_empty() {
            return;
        }
        let version = job.session.version();
        let mut bytes = 0u64;
        let mut pushes = 0u64;
        let policy = self.health.policy();
        for (pi, peer) in peers.iter().enumerate() {
            for f in &job.files {
                let rel = format!("{}/{f}", job.dir);
                // transient push failures retry in place under the
                // pipeline's policy; the per-attempt torn peer copy is
                // overwritten by the retried `create`
                let (res, _retries) = policy
                    .run(fnv1a(rel.as_bytes()) ^ pi as u64, || {
                        self.replicate_file(peer.as_ref(), &rel,
                                            throttle.as_deref())
                    });
                match res {
                    Ok(n) => {
                        bytes += n;
                        pushes += 1;
                        job.session.progress_counters().add_drained(n);
                    }
                    Err(e) => {
                        eprintln!(
                            "[storage] replica v{version} {rel} -> peer \
                             {pi} failed: {e:#}"
                        );
                        job.session.fail_replica(format!(
                            "push of {rel} to peer {pi}: {e:#}"
                        ));
                        return;
                    }
                }
            }
        }
        job.session.replica_durable(
            job.requested.elapsed().as_secs_f64(),
            bytes,
            pushes,
        );
        if let Some(n) = &job.notify {
            n.notify();
        }
    }

    /// Drain one finalized version hop by hop until it reaches the
    /// terminal tier, marking per-tier durability as each hop lands.
    /// Replica pushes run first, off the still-resident landing copy.
    ///
    /// Circuit-breaker semantics (ISSUE 10): a QUARANTINED destination
    /// tier is skipped — its durability level degrades (waiters error by
    /// name instead of hanging), the hop is queued for retry on
    /// recovery, and the drain continues from the last landed tier to
    /// the next deeper one, so a single sick tier can never wedge the
    /// queue or block terminal persistence. Permanent copy failures on
    /// an admitted tier keep the pre-existing fail-the-version
    /// semantics.
    fn drain_version(&self, job: VersionDrainJob) {
        let version = job.session.version();
        self.replicate_version(&job);
        // the tier currently holding the newest landed copy: hops that
        // skip a quarantined tier drain from here to the next one
        let mut src = 0usize;
        for to in 1..self.tiers.len() {
            let to_label = self.tiers[to].kind().label();
            if self.health.tier(to).admit() == Admission::Deny {
                let reason = format!(
                    "{to_label} tier quarantined; drain hop skipped \
                     (queued for retry on recovery)"
                );
                eprintln!("[storage] drain v{version}: {reason}");
                job.session.tier_degraded(to, reason);
                self.pending_hops.lock().unwrap().push(PendingHop {
                    version,
                    dir: job.dir.clone(),
                    files: job.files.clone(),
                    to,
                });
                continue;
            }
            let mut hop_err: Option<anyhow::Error> = None;
            for f in &job.files {
                let rel = format!("{}/{f}", job.dir);
                if let Err(e) = self
                    .drain_file_retry(src, to, &rel, Some(&job.session))
                {
                    eprintln!(
                        "[storage] drain v{version} {} -> {} failed: \
                         {e:#}",
                        self.tiers[src].kind().label(),
                        to_label
                    );
                    hop_err = Some(e);
                    break;
                }
            }
            if let Some(e) = hop_err {
                // the breaker recorded the failures; if they just
                // tripped quarantine, degrade only this level and keep
                // draining deeper — otherwise preserve the historical
                // fail-the-version semantics
                if self.health.tier(to).is_quarantined() {
                    job.session.tier_degraded(
                        to,
                        format!("{to_label} tier quarantined mid-hop: \
                                 {e:#}"),
                    );
                    self.pending_hops.lock().unwrap().push(PendingHop {
                        version,
                        dir: job.dir.clone(),
                        files: job.files.clone(),
                        to,
                    });
                    continue;
                }
                job.session
                    .fail(format!("tier drain to {to_label}: {e:#}"));
                return;
            }
            // the hop is complete: evict the volatile copy, record
            // residency, then resolve this tier's durability future
            if self.evict_fast
                && self.tiers[src].kind() == TierKind::HostCache
            {
                for f in &job.files {
                    let rel = format!("{}/{f}", job.dir);
                    let _ = self.tiers[src].remove(&rel);
                }
                self.manifest.set(version, &job.files, src, false);
            }
            self.manifest.set(version, &job.files, to, true);
            // resolve the durability future FIRST — the payload is
            // already durable; the manifest rewrite is advisory (restore
            // falls back to tier scans) and must not delay waiters
            job.session
                .tier_durable(to, job.requested.elapsed().as_secs_f64());
            // evictions freed landing-tier space: wake a pump that is
            // deferring admissions on capacity
            if let Some(n) = &job.notify {
                n.notify();
            }
            self.persist_manifest();
            src = to;
        }
    }

    /// Retry drain hops skipped while their destination tier was
    /// quarantined. Runs on the drain worker between jobs (and from the
    /// scrubber): each hop whose tier readmits (half-open probe) is
    /// copied from the nearest tier still holding the version; success
    /// feeds the breaker toward reintegration and records residency.
    /// Returns how many hops landed.
    fn retry_pending_hops(&self) -> u64 {
        let hops: Vec<PendingHop> = {
            let mut g = self.pending_hops.lock().unwrap();
            std::mem::take(&mut *g)
        };
        if hops.is_empty() {
            return 0;
        }
        let mut landed = 0u64;
        let mut keep: Vec<PendingHop> = Vec::new();
        for hop in hops {
            if self.health.tier(hop.to).admit() == Admission::Deny {
                keep.push(hop);
                continue;
            }
            let mut ok = true;
            for f in &hop.files {
                let rel = format!("{}/{f}", hop.dir);
                // nearest tier (excluding the destination) holding the
                // file serves as the rebuild source
                let src = self
                    .tiers
                    .iter()
                    .position(|t| t.exists(&rel))
                    .filter(|&i| i != hop.to);
                let res = match src {
                    Some(i) => {
                        self.drain_file_retry(i, hop.to, &rel, None)
                    }
                    None => Err(anyhow::anyhow!(
                        "{rel}: no tier holds a copy to resume the \
                         skipped hop from"
                    )),
                };
                if let Err(e) = res {
                    eprintln!(
                        "[storage] resume of skipped hop v{} -> {} \
                         failed: {e:#}",
                        hop.version,
                        self.tiers[hop.to].kind().label()
                    );
                    ok = false;
                    break;
                }
            }
            if ok {
                eprintln!(
                    "[storage] resumed skipped drain hop: v{} now on \
                     {} tier",
                    hop.version,
                    self.tiers[hop.to].kind().label()
                );
                self.manifest
                    .set(hop.version, &hop.files, hop.to, true);
                self.persist_manifest();
                landed += 1;
            } else {
                keep.push(hop);
            }
        }
        if !keep.is_empty() {
            let mut g = self.pending_hops.lock().unwrap();
            // hops queued while we were retrying stay behind the ones
            // we put back
            keep.extend(g.drain(..));
            *g = keep;
        }
        landed
    }

    /// Tier-health registry (restore-engine sources consult it too).
    pub(crate) fn health(&self) -> &HealthRegistry {
        &self.health
    }

    /// The armed fault injector, cloned — restore-side hooks
    /// (transient-read and slow-tier injection) share the pipeline's.
    pub(crate) fn injector(&self) -> Option<Arc<FaultInjector>> {
        self.fault_injector()
    }

    /// File set of a version (see `TierPipeline::version_files`).
    fn version_files_impl(&self, version: u64, dir: &str)
        -> anyhow::Result<Vec<String>> {
        if let Some(files) = self.manifest.files(version) {
            let all_present = !files.is_empty()
                && files.iter().all(|f| {
                    let rel = format!("{dir}/{f}");
                    self.tiers.iter().any(|t| t.exists(&rel))
                });
            if all_present {
                return Ok(files);
            }
        }
        let mut files: Vec<String> = Vec::new();
        for tier in &self.tiers {
            for f in tier.list(dir)? {
                if !files.contains(&f) {
                    files.push(f);
                }
            }
        }
        files.sort();
        Ok(files)
    }

    // ---- scrub-and-repair (ISSUE 10) ------------------------------------

    /// Scrub one version's copies across all tiers: structurally verify
    /// each copy (trailer parse + full payload read) and checksum it
    /// (FNV over the raw bytes — the trailer-level integrity check for
    /// local/host tiers; remote copies additionally re-hash every chunk
    /// inside their content-addressed reader). Torn or bit-rotted
    /// copies are rebuilt from the deepest verified tier copy, or from
    /// a peer replica tree when no local tier holds a good one.
    fn scrub_version(&self, version: u64, dir: &str, files: &[String],
                     rep: &mut ScrubReport) {
        for f in files {
            let rel = format!("{dir}/{f}");
            rep.files_checked += 1;
            let mut good: Vec<(usize, u64)> = Vec::new();
            let mut bad: Vec<usize> = Vec::new();
            for (i, tier) in self.tiers.iter().enumerate() {
                if !tier.exists(&rel) {
                    continue;
                }
                match verify_copy(tier.as_ref(), &rel) {
                    Ok(h) => good.push((i, h)),
                    Err(e) => {
                        eprintln!(
                            "[scrub] v{version} {rel} torn on {} tier: \
                             {e:#}",
                            tier.kind().label()
                        );
                        bad.push(i);
                    }
                }
            }
            // bit-rot: a structurally-valid copy whose checksum
            // disagrees with the DEEPEST verified copy is rotted
            if let Some(&(_, ref_hash)) = good.last() {
                let (keep, rot): (Vec<_>, Vec<_>) = good
                    .into_iter()
                    .partition(|&(_, h)| h == ref_hash);
                for (i, _) in rot {
                    eprintln!(
                        "[scrub] v{version} {rel}: checksum mismatch \
                         on {} tier (bit rot)",
                        self.tiers[i].kind().label()
                    );
                    bad.push(i);
                }
                good = keep;
            }
            rep.copies_verified += good.len() as u64;
            for &i in &bad {
                match self.rebuild_copy(i, &rel, &good) {
                    Ok(()) => {
                        eprintln!(
                            "[scrub] v{version} {rel}: rebuilt on {} \
                             tier",
                            self.tiers[i].kind().label()
                        );
                        rep.copies_repaired += 1;
                    }
                    Err(e) => rep.unrepairable.push(format!(
                        "{rel} on {} tier: {e:#}",
                        self.tiers[i].kind().label()
                    )),
                }
            }
        }
    }

    /// Rebuild tier `to`'s copy of `rel` from the deepest verified tier
    /// copy, falling back to peer replica trees; the rebuilt copy is
    /// re-verified (and checksum-matched when a reference exists).
    fn rebuild_copy(&self, to: usize, rel: &str,
                    good: &[(usize, u64)]) -> anyhow::Result<()> {
        if let Some(&(src, want)) = good.last() {
            self.drain_file_retry(src, to, rel, None)?;
            let h = verify_copy(self.tiers[to].as_ref(), rel)?;
            anyhow::ensure!(
                h == want,
                "{rel}: rebuilt copy checksum mismatch on {} tier",
                self.tiers[to].kind().label()
            );
            return Ok(());
        }
        let peers = self.replicas.lock().unwrap().peers.clone();
        for (pi, peer) in peers.iter().enumerate() {
            if !peer.exists(rel) {
                continue;
            }
            let res = self
                .copy_from_backend(peer.as_ref(), to, rel)
                .and_then(|_| {
                    verify_copy(self.tiers[to].as_ref(), rel)
                        .map(|_| ())
                });
            match res {
                Ok(()) => return Ok(()),
                Err(e) => eprintln!(
                    "[scrub] rebuild of {rel} from peer {pi} failed: \
                     {e:#}"
                ),
            }
        }
        anyhow::bail!(
            "no verified copy on any tier or peer to rebuild from"
        )
    }

    /// Raw chunked copy from an arbitrary backend (a peer replica tree)
    /// into tier `to`.
    fn copy_from_backend(&self, src: &dyn Backend, to: usize,
                         rel: &str) -> anyhow::Result<u64> {
        let s = src.open(rel)?;
        let len = s.len()?;
        let d = self.tiers[to].create(rel)?;
        let mut buf =
            vec![0u8; self.chunk_bytes.min(len.max(1) as usize)];
        let mut off = 0u64;
        while off < len {
            let take = ((len - off) as usize).min(buf.len());
            s.read_exact_at(&mut buf[..take], off)?;
            d.write_at(off, &buf[..take])?;
            off += take as u64;
        }
        d.finalize()?;
        Ok(len)
    }

    /// Full scrub sweep: resume skipped drain hops, then verify (and
    /// repair) every manifest-recorded version.
    fn scrub_all(&self) -> anyhow::Result<ScrubReport> {
        let mut rep = ScrubReport::default();
        rep.hops_resumed = self.retry_pending_hops();
        for version in self.manifest.versions() {
            let dir = format!("v{version:06}");
            let files = self.version_files_impl(version, &dir)?;
            self.scrub_version(version, &dir, &files, &mut rep);
        }
        Ok(rep)
    }
}

/// Verify one tier copy end to end: structural validation (footer,
/// trailer, every extent and object via `restore::read_from`) plus an
/// FNV-1a checksum over the raw bytes for cross-tier comparison.
fn verify_copy(tier: &dyn Backend, rel: &str) -> anyhow::Result<u64> {
    let r = tier.open(rel)?;
    let len = r.len()? as usize;
    let mut buf = vec![0u8; len];
    r.read_exact_at(&mut buf, 0)?;
    let hash = fnv1a(&buf);
    crate::restore::read_from(tier.open(rel)?)?;
    Ok(hash)
}

/// What a scrub pass found and fixed.
#[derive(Debug, Default, Clone)]
pub struct ScrubReport {
    /// Version files walked.
    pub files_checked: u64,
    /// Tier copies that verified clean (parse + checksum).
    pub copies_verified: u64,
    /// Torn/bit-rotted copies rebuilt (and re-verified) from a
    /// surviving tier or peer.
    pub copies_repaired: u64,
    /// Quarantine-skipped drain hops landed by this pass.
    pub hops_resumed: u64,
    /// Copies with no verified source to rebuild from.
    pub unrepairable: Vec<String>,
}

/// The composable tier stack. Single-tier pipelines are degenerate
/// (landing == terminal, drains rejected unless peer replication is
/// installed) and behave exactly like the old flat flush path.
pub struct TierPipeline {
    shared: Arc<PipelineShared>,
    drain_tx: Option<Sender<VersionDrainJob>>,
    worker: Option<JoinHandle<()>>,
}

impl TierPipeline {
    pub fn new(tiers: Vec<Arc<dyn Backend>>, evict_fast: bool,
               chunk_bytes: usize, timeline: Arc<Timeline>)
        -> Arc<TierPipeline> {
        assert!(!tiers.is_empty(), "pipeline needs at least one tier");
        let kinds: Vec<TierKind> =
            tiers.iter().map(|t| t.kind()).collect();
        let manifest =
            Manifest::load(tiers.last().unwrap().as_ref(), kinds);
        let n_tiers = tiers.len();
        let shared = Arc::new(PipelineShared {
            tiers,
            manifest,
            timeline,
            evict_fast,
            chunk_bytes: chunk_bytes.max(1),
            drains_pending: std::sync::atomic::AtomicUsize::new(0),
            read_cfg: Mutex::new(Default::default()),
            replicas: Mutex::new(ReplicaTargets::default()),
            faults: Mutex::new(None),
            health: HealthRegistry::new(n_tiers),
            pending_hops: Mutex::new(Vec::new()),
            scrub: std::sync::atomic::AtomicBool::new(false),
        });
        // the worker is spawned unconditionally (it parks on the job
        // channel): single-tier pipelines need it too once peer
        // replication is installed, and `set_replicas` runs after
        // construction — `submit_drain` still rejects jobs that have
        // nothing to do (single tier, no replicas)
        let (tx, rx) =
            crate::util::channel::unbounded::<VersionDrainJob>();
        let sh = shared.clone();
        let handle = std::thread::Builder::new()
            .name("ds-tier-drain".into())
            .spawn(move || Self::drain_loop(rx, sh))
            .expect("spawn tier drain");
        Arc::new(TierPipeline {
            shared,
            drain_tx: Some(tx),
            worker: Some(handle),
        })
    }

    /// Degenerate single-tier pipeline (the baselines' flat path).
    pub fn single(backend: Arc<dyn Backend>, timeline: Arc<Timeline>)
        -> Arc<TierPipeline> {
        Self::new(vec![backend], false, 4 << 20, timeline)
    }

    /// Build from declarative specs. The LAST `LocalFs` spec roots at
    /// `ckpt_dir` (so on-disk layouts match the flat engine's); any
    /// earlier filesystem tier gets a `tier{i}` subdirectory.
    /// `host_cache_capacity` bounds host-cache residency (admission
    /// backpressure) — applied only when eviction is on AND a deeper
    /// tier exists, since only the drain worker's evictions ever free
    /// space; a capacity on a drain-less cache could never be respected.
    pub fn from_specs(specs: &[TierSpec], ckpt_dir: &Path,
                      evict_fast: bool, chunk_bytes: usize,
                      host_cache_capacity: Option<usize>,
                      timeline: Arc<Timeline>)
        -> anyhow::Result<Arc<TierPipeline>> {
        anyhow::ensure!(!specs.is_empty(), "tier stack is empty");
        let cache_capacity = if evict_fast && specs.len() > 1 {
            host_cache_capacity
        } else {
            None
        };
        let last_fs = specs
            .iter()
            .rposition(|s| s.kind == TierKind::LocalFs);
        let last_remote = specs
            .iter()
            .rposition(|s| s.kind == TierKind::Remote);
        let mut tiers: Vec<Arc<dyn Backend>> =
            Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let tier: Arc<dyn Backend> = match spec.kind {
                TierKind::HostCache => Arc::new(HostCache::build(
                    spec.throttle_bps,
                    cache_capacity,
                )),
                TierKind::LocalFs => {
                    let root = if Some(i) == last_fs {
                        ckpt_dir.to_path_buf()
                    } else {
                        ckpt_dir.join(format!("tier{i}"))
                    };
                    match (spec.uring_depth, spec.throttle_bps) {
                        // with_uring probes at construction and falls
                        // back to the thread-pool path on refusal
                        (Some(depth), bps) => Arc::new(
                            LocalFs::with_uring(root, bps, depth)),
                        (None, Some(bps)) => {
                            Arc::new(LocalFs::throttled(root, bps))
                        }
                        (None, None) => Arc::new(LocalFs::new(root)),
                    }
                }
                TierKind::Remote => {
                    // a stable root for the LAST remote spec, so a
                    // later remote-only stack over the same ckpt_dir
                    // resolves the same store (restart / DR restore)
                    let root = if Some(i) == last_remote {
                        ckpt_dir.join("remote")
                    } else {
                        ckpt_dir.join(format!("remote{i}"))
                    };
                    Arc::new(RemoteStore::open(
                        &root,
                        spec.content_chunk_bytes
                            .unwrap_or(DEFAULT_CONTENT_CHUNK_BYTES),
                        spec.latency_s,
                        spec.throttle_bps,
                    )?)
                }
                TierKind::Replicated => anyhow::bail!(
                    "`replicated` is a durability level, not a \
                     storable tier — configure peers via \
                     `EngineConfig::replicas` (or `--replicas K`) \
                     instead of the tier stack"
                ),
            };
            tiers.push(tier);
        }
        Ok(Self::new(tiers, evict_fast, chunk_bytes, timeline))
    }

    fn drain_loop(rx: Receiver<VersionDrainJob>, shared: Arc<PipelineShared>) {
        use std::sync::atomic::Ordering;
        // event-driven: parks on the job channel; exits on disconnect
        // after draining every queued version
        while let Ok(job) = rx.recv() {
            let notify = job.notify.clone();
            let (version, dir, files) = (
                job.session.version(),
                job.dir.clone(),
                job.files.clone(),
            );
            shared.drain_version(job);
            shared.drains_pending.fetch_sub(1, Ordering::AcqRel);
            if let Some(n) = notify {
                n.notify();
            }
            // self-healing between jobs: land any drain hops skipped
            // while their tier was quarantined, and (when the scrubber
            // is on) re-verify the version just drained
            shared.retry_pending_hops();
            if shared.scrub.load(Ordering::Relaxed) {
                let mut rep = ScrubReport::default();
                shared.scrub_version(version, &dir, &files, &mut rep);
                if rep.copies_repaired > 0
                    || !rep.unrepairable.is_empty()
                {
                    eprintln!(
                        "[scrub] v{version}: {} repaired, {} \
                         unrepairable",
                        rep.copies_repaired,
                        rep.unrepairable.len()
                    );
                }
            }
        }
    }

    pub fn n_tiers(&self) -> usize {
        self.shared.tiers.len()
    }

    pub fn is_multi(&self) -> bool {
        self.n_tiers() > 1
    }

    pub fn tiers(&self) -> &[Arc<dyn Backend>] {
        &self.shared.tiers
    }

    /// The landing (fastest) tier — where the flush pool writes.
    pub fn landing(&self) -> &Arc<dyn Backend> {
        &self.shared.tiers[0]
    }

    /// The terminal (most durable) tier.
    pub fn terminal(&self) -> &Arc<dyn Backend> {
        self.shared.terminal()
    }

    /// Tier kinds, fastest first (checkpoint sessions index durability
    /// by this).
    pub fn tier_kinds(&self) -> Vec<TierKind> {
        self.shared.tiers.iter().map(|t| t.kind()).collect()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.shared.manifest
    }

    /// Install peer-replication targets: one `LocalFs` backend per
    /// peer directory, plus the shared replication-bandwidth throttle.
    /// Subsequent drain jobs mirror their version to every peer before
    /// the first tier hop. An empty spec switches replication off.
    pub fn set_replicas(&self, spec: &ReplicaSpec) {
        let mut st = self.shared.replicas.lock().unwrap();
        st.peers = spec
            .peers
            .iter()
            .map(|p| Arc::new(LocalFs::new(p)) as Arc<dyn Backend>)
            .collect();
        st.throttle =
            spec.throttle_bps.map(|bps| Arc::new(Throttle::new(bps)));
    }

    /// Replication factor K currently installed (0 = off).
    pub fn replicas_active(&self) -> usize {
        self.shared.replicas.lock().unwrap().peers.len()
    }

    /// Arm the pipeline's fault-injection hooks (`figures faults`);
    /// `None` removes them.
    pub fn set_fault_injector(&self,
                              inj: Option<Arc<FaultInjector>>) {
        *self.shared.faults.lock().unwrap() = inj;
    }

    /// Tier-health registry: per-tier circuit breakers + retry policy.
    pub fn health(&self) -> &HealthRegistry {
        self.shared.health()
    }

    /// Install the transient-retry policy every I/O path of this
    /// pipeline runs under (the `--retry-max` knob).
    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        self.shared.health.set_policy(policy);
    }

    /// Toggle the background scrubber: when on, the drain worker
    /// re-verifies each version after draining it and rebuilds torn or
    /// bit-rotted copies (the `--scrub` knob).
    pub fn set_scrub(&self, on: bool) {
        self.shared
            .scrub
            .store(on, std::sync::atomic::Ordering::Relaxed);
    }

    /// One full scrub-and-repair sweep over every manifest-recorded
    /// version (the `fsck --repair`-style at-rest pass): resume skipped
    /// drain hops, verify each tier copy (trailer parse + payload
    /// checksum; remote chunks re-hash in their reader), rebuild torn
    /// or rotted copies from the deepest verified tier or a peer
    /// replica tree.
    pub fn scrub_repair(&self) -> anyhow::Result<ScrubReport> {
        self.shared.scrub_all()
    }

    /// Drain hops currently queued awaiting a quarantined tier's
    /// recovery.
    pub fn pending_hops(&self) -> usize {
        self.shared.pending_hops.lock().unwrap().len()
    }

    /// Create a file on the landing tier (the engine flush path).
    pub fn create_landing(&self, rel: &str)
        -> anyhow::Result<Box<dyn BackendFile>> {
        if let Some(inj) = self.shared.fault_injector() {
            if inj.check(KillPoint::MidCapture) {
                anyhow::bail!(
                    "fault injected: mid-capture (landing create of \
                     {rel} aborted)"
                );
            }
        }
        self.landing().create(rel)
    }

    /// Submit a version whose landing-tier copy is finalized for
    /// background tier-to-tier draining (and/or peer replication).
    pub fn submit_drain(&self, job: VersionDrainJob) -> anyhow::Result<()> {
        use std::sync::atomic::Ordering;
        if !self.is_multi() && self.replicas_active() == 0 {
            anyhow::bail!("single-tier pipeline");
        }
        let tx = self
            .drain_tx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("single-tier pipeline"))?;
        self.shared.drains_pending.fetch_add(1, Ordering::AcqRel);
        if let Err(e) = tx.send(job) {
            self.shared.drains_pending.fetch_sub(1, Ordering::AcqRel);
            drop(e);
            anyhow::bail!("tier drain worker dead");
        }
        Ok(())
    }

    /// Versions submitted to the drain worker and not yet finished.
    pub fn drains_pending(&self) -> usize {
        self.shared
            .drains_pending
            .load(std::sync::atomic::Ordering::Acquire)
    }

    /// Admission backpressure: false while the landing tier reports
    /// itself over capacity — the pump should defer NEW versions (it
    /// wakes on the drain worker's eviction notifications) but never
    /// stall versions already landing. Unbounded tiers always admit.
    pub fn landing_admissible(&self) -> bool {
        match self.landing().capacity_status() {
            Some((resident, capacity)) => resident < capacity,
            None => true,
        }
    }

    /// Record a version written directly to the terminal tier (the
    /// degenerate single-tier path, and the engine pump's completion
    /// path). In-memory only — cheap enough for the pump thread and
    /// synchronous engines; the manifest file is rewritten by the drain
    /// worker (multi-tier) and at pipeline drop. A crash loses only the
    /// manifest, and restore falls back to tier scans.
    pub fn record_terminal_complete(&self, version: u64, files: &[String]) {
        let idx = self.n_tiers() - 1;
        self.shared.manifest.set(version, files, idx, true);
    }

    /// Rewrite the persisted manifest on the terminal tier now.
    pub fn persist_manifest(&self) {
        self.shared.persist_manifest();
    }

    // ---- restore side -------------------------------------------------

    /// File set of a version: from the manifest when recorded — unless
    /// a recorded file exists on NO tier (a stale or corrupt record must
    /// not veto a checkpoint that is intact on disk) — else the union of
    /// per-tier directory listings.
    fn version_files(&self, version: u64, dir: &str)
        -> anyhow::Result<Vec<String>> {
        self.shared.version_files_impl(version, dir)
    }

    /// File names of a version (manifest when trustworthy, else the
    /// union of per-tier listings) — the reshard planner's view of a
    /// source rank's checkpoint.
    pub fn version_file_names(&self, version: u64)
        -> anyhow::Result<Vec<String>> {
        self.version_files(version, &format!("v{version:06}"))
    }

    /// Open `rel` on the nearest tier holding a copy and hand the
    /// reader to `parse`, falling through to deeper tiers on missing or
    /// torn (unparsable) copies. The single home of the torn-copy
    /// fall-through policy — every nearest-tier read path funnels
    /// through here.
    fn open_nearest<T>(
        &self,
        rel: &str,
        parse: impl Fn(Box<dyn ReadAt>) -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        // Every tier's failure is kept, not just the last: when a
        // chunk is torn on the remote tier the joined error names the
        // file, each failing tier, and the offending chunk id, instead
        // of whichever tier happened to fail last.
        let mut errs: Vec<String> = Vec::new();
        let fault = self.shared.fault_injector();
        let policy = self.shared.health.policy();
        for (i, tier) in self.shared.tiers.iter().enumerate() {
            if !tier.exists(rel) {
                continue;
            }
            if let Some(inj) = &fault {
                // fires ONCE per arm: the nearest holder's probe fails
                // and resolution must fall through to a deeper tier or
                // peer copy
                if inj.check(KillPoint::MidRestore) {
                    errs.push(format!(
                        "on {} tier: fault injected: mid-restore",
                        tier.kind().label()
                    ));
                    continue;
                }
            }
            // transient open/parse failures (EINTR/EAGAIN-shaped)
            // retry IN PLACE under the pipeline's policy — only
            // permanent errors (torn/truncated copies) demote the read
            // to a deeper tier
            let label = tier.kind().label();
            let breaker = self.shared.health.tier(i);
            let t0 = Instant::now();
            let (res, _retries) =
                policy.run(fnv1a(rel.as_bytes()), || {
                    if let Some(inj) = &fault {
                        if let Some(e) =
                            inj.transient_error("open", label)
                        {
                            return Err(e);
                        }
                    }
                    tier.open(rel).and_then(&parse)
                });
            match res {
                Ok(v) => {
                    breaker.record_ok(t0.elapsed().as_secs_f64());
                    return Ok(v);
                }
                Err(e) => {
                    // torn/truncated on this tier: try the next one
                    breaker.record_err();
                    errs.push(format!("on {label} tier: {e:#}"));
                }
            }
        }
        Err(if errs.is_empty() {
            anyhow::anyhow!("{rel}: not found on any tier")
        } else {
            anyhow::anyhow!("{rel}: no tier holds a readable copy: {}",
                            errs.join("; "))
        })
    }

    /// Open one checkpoint file of a version as a positioned-read chunk
    /// stream from the nearest tier holding a readable copy, falling
    /// through on missing or torn (unparsable-trailer) copies — the
    /// streaming sibling of [`TierPipeline::read_file_nearest`], used by
    /// the reshard executor to pull sub-ranges of entries without
    /// materializing whole files.
    pub fn chunk_source_nearest(&self, rel: &str)
        -> anyhow::Result<crate::restore::ChunkSource> {
        self.open_nearest(rel, |r| {
            crate::restore::ChunkSource::from_reader(
                r,
                crate::restore::source::DEFAULT_CHUNK_BYTES,
            )
        })
    }

    /// Read one checkpoint file from the nearest tier holding a readable
    /// copy, falling through on missing or torn files.
    pub fn read_file_nearest(&self, rel: &str)
        -> anyhow::Result<RestoredFile> {
        self.open_nearest(rel, crate::restore::read_from)
    }

    /// Cheap completeness check: every file of `version` has a parsable
    /// self-describing copy on some tier. The trailer + footer are
    /// written only after every payload write landed
    /// (`FlushFile::finalize`), so a successful parse implies the whole
    /// file is present — unlike [`TierPipeline::read_version`] this
    /// reads no payload bytes, which is what the distributed commit
    /// vote needs (verifying N versions must not re-read N checkpoints).
    pub fn version_readable(&self, version: u64) -> anyhow::Result<()> {
        let dir = format!("v{version:06}");
        let files = self.version_files(version, &dir)?;
        anyhow::ensure!(!files.is_empty(),
                        "no files recorded or stored for v{version}");
        for f in &files {
            self.chunk_source_nearest(&format!("{dir}/{f}"))?;
        }
        Ok(())
    }

    /// Install the restore-engine knobs this pipeline's read paths use
    /// (called by the checkpoint engines with their
    /// `EngineConfig`-derived settings, so `restore_lanes` /
    /// `reader_threads` take effect on every default restore path).
    pub fn set_restore_config(&self,
                              cfg: crate::restore::ReadEngineConfig) {
        // tiers that size per-handle state from reader concurrency
        // (the remote chunk LRU) hear about the new fan-out
        for t in &self.shared.tiers {
            t.set_read_concurrency(cfg.readers.max(cfg.fs_readers));
        }
        *self.shared.read_cfg.lock().unwrap() = cfg;
    }

    /// Ring attribution summed across every tier that runs an io_uring
    /// (`None` when no tier does — probe refused or not requested).
    pub fn uring_stats(&self) -> Option<UringStats> {
        self.shared.uring_stats_agg()
    }

    /// The `Arc`-shared tier state backing this pipeline — what a
    /// restore-engine pass source holds so sealed gather runs carry no
    /// pipeline borrows (persistent serving workers outlive any one
    /// caller's borrow of the pipeline handle).
    pub(crate) fn shared_state(&self) -> Arc<PipelineShared> {
        self.shared.clone()
    }

    /// Offer the pinned staging slab to every tier for fixed-buffer
    /// registration (no-op on tiers without a ring).
    pub fn register_pinned(&self, ptr: *const u8, len: usize,
                           keep: Arc<dyn std::any::Any + Send + Sync>) {
        for t in &self.shared.tiers {
            t.register_pinned(ptr, len, keep.clone());
        }
    }

    /// The restore-engine knobs currently installed on this pipeline.
    pub fn restore_config(&self) -> crate::restore::ReadEngineConfig {
        self.shared.read_cfg.lock().unwrap().clone()
    }

    /// Read every file of a checkpoint version, each from its nearest
    /// readable tier, through the parallel restore engine (coalesced
    /// gather reads, tier-aware reader pool, multi-lane H2D upload —
    /// see `restore::ReadEngine`). Byte-identical to
    /// [`TierPipeline::read_version_serial`], property-tested.
    pub fn read_version(&self, version: u64)
        -> anyhow::Result<RestoredVersion> {
        crate::restore::ReadEngine::new(self.restore_config())
            .read_version(self, version)
    }

    /// The serial reference restore path: one positioned read per
    /// extent, one file at a time. Kept as the byte oracle the parallel
    /// engine is tested against (and as the zero-thread fallback).
    pub fn read_version_serial(&self, version: u64)
        -> anyhow::Result<RestoredVersion> {
        let dir = format!("v{version:06}");
        let files = self.version_files(version, &dir)?;
        anyhow::ensure!(!files.is_empty(),
                        "no files recorded or stored for v{version}");
        let mut out = RestoredVersion::new();
        for f in &files {
            let rf = self.read_file_nearest(&format!("{dir}/{f}"))?;
            out.insert(f.clone(), rf);
        }
        Ok(out)
    }

    /// Every version known to the pipeline (manifest ∪ tier scans),
    /// ascending.
    pub fn versions(&self) -> anyhow::Result<Vec<u64>> {
        let mut vs = self.shared.manifest.versions();
        for tier in &self.shared.tiers {
            for d in tier.list_dirs("")? {
                if let Some(v) = d
                    .strip_prefix('v')
                    .and_then(|s| s.parse::<u64>().ok())
                {
                    vs.push(v);
                }
            }
        }
        vs.sort_unstable();
        vs.dedup();
        Ok(vs)
    }

    /// Restore the newest version with a complete readable copy, walking
    /// versions newest-first and tiers nearest-first. One parallel
    /// restore engine (and its staging pool) is reused across the walk.
    pub fn restore_newest(&self)
        -> anyhow::Result<Option<(u64, RestoredVersion)>> {
        crate::restore::ReadEngine::new(self.restore_config())
            .restore_newest(self)
    }
}

impl Drop for TierPipeline {
    fn drop(&mut self) {
        // disconnect the job channel; the worker drains queued versions,
        // then exits on the disconnect
        drop(self.drain_tx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        // final manifest rewrite (the in-memory record may be ahead of
        // the persisted one on single-tier pipelines)
        if !self.shared.manifest.versions().is_empty() {
            self.shared.persist_manifest();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_kinds() -> Vec<TierKind> {
        vec![TierKind::HostCache, TierKind::LocalFs]
    }

    #[test]
    fn manifest_roundtrip_through_terminal_tier() {
        let dir = crate::util::TempDir::new("manifest").unwrap();
        let fs: Arc<dyn Backend> = Arc::new(LocalFs::new(dir.path()));
        let m = Manifest::new(two_kinds());
        m.set(3, &["a.pt".into(), "b.pt".into()], 1, true);
        m.set(3, &[], 0, true);
        m.set(7, &["c.pt".into()], 1, true);
        let text = m.encode();
        let f = fs.create(MANIFEST_FILE).unwrap();
        f.write_at(0, text.as_bytes()).unwrap();
        f.finalize().unwrap();

        let loaded = Manifest::load(fs.as_ref(), two_kinds());
        assert_eq!(loaded.versions(), vec![3, 7]);
        assert_eq!(loaded.lives_on(3), vec![0, 1]);
        assert_eq!(loaded.lives_on(7), vec![1]);
        assert_eq!(loaded.files(3).unwrap(),
                   vec!["a.pt".to_string(), "b.pt".to_string()]);
        assert!(loaded.lives_on(99).is_empty());
    }

    #[test]
    fn manifest_tolerates_garbage_lines() {
        let m = Manifest::new(two_kinds());
        m.parse_into("# comment\n\nnot-a-version\tx\ty\n5\t01\tf.pt\n");
        assert_eq!(m.versions(), vec![5]);
        assert_eq!(m.lives_on(5), vec![1]);
    }

    #[test]
    fn manifest_columns_map_by_tier_kind_across_configs() {
        // written by a single-tier (LocalFs-only) engine...
        let single = Manifest::new(vec![TierKind::LocalFs]);
        single.set(4, &["f.pt".into()], 0, true);
        let text = single.encode();

        // ...read under a two-tier config: the LocalFs column must land
        // on tier 1, NOT on the volatile host cache at index 0
        let two = Manifest::new(two_kinds());
        two.parse_into(&text);
        assert_eq!(two.lives_on(4), vec![1]);

        // and back: a two-tier manifest read single-tier keeps only the
        // LocalFs residency
        let two2 = Manifest::new(two_kinds());
        two2.set(9, &["g.pt".into()], 0, true);
        two2.set(9, &[], 1, true);
        let single2 = Manifest::new(vec![TierKind::LocalFs]);
        single2.parse_into(&two2.encode());
        assert_eq!(single2.lives_on(9), vec![0]);
    }

    #[test]
    fn single_tier_pipeline_rejects_drains_without_replicas() {
        let dir = crate::util::TempDir::new("pipe-single").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::single(
            Arc::new(LocalFs::new(dir.path())), tl);
        assert!(!p.is_multi());
        assert_eq!(p.tier_kinds(), vec![TierKind::LocalFs]);
        assert_eq!(p.replicas_active(), 0);
        assert!(p
            .submit_drain(VersionDrainJob {
                session: CkptSession::new(
                    0,
                    None,
                    Arc::new(crate::metrics::ProgressCounters::default()),
                    Default::default(),
                    vec![TierKind::LocalFs],
                ),
                requested: Instant::now(),
                dir: "v000000".into(),
                files: vec![],
                notify: None,
            })
            .is_err());
    }

    fn replica_session(version: u64) -> Arc<CkptSession> {
        let s = CkptSession::new(
            version,
            None,
            Arc::new(crate::metrics::ProgressCounters::default()),
            Default::default(),
            vec![TierKind::LocalFs],
        );
        s.expect_replicas();
        s
    }

    #[test]
    fn replicas_mirror_versions_to_peers_byte_identically() {
        let dir = crate::util::TempDir::new("pipe-replica").unwrap();
        let peer = crate::util::TempDir::new("pipe-peer").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::single(
            Arc::new(LocalFs::new(dir.path())), tl);
        p.set_replicas(&ReplicaSpec::to_peers(vec![
            peer.path().to_path_buf()
        ]));
        assert_eq!(p.replicas_active(), 1);
        let payload = vec![42u8; 10_000];
        let f = p.create_landing("v000001/x").unwrap();
        f.write_at(0, &payload).unwrap();
        f.finalize().unwrap();
        let s = replica_session(1);
        p.submit_drain(VersionDrainJob {
            session: s.clone(),
            requested: Instant::now(),
            dir: "v000001".into(),
            files: vec!["x".into()],
            notify: None,
        })
        .unwrap();
        let t = crate::CheckpointTicket::new(s);
        let m = t.wait_durable(TierKind::Replicated).unwrap();
        assert_eq!(m.replica_pushes, 1);
        assert_eq!(m.replica_bytes, 10_000);
        assert!(t.is_durable(TierKind::Replicated));
        assert_eq!(std::fs::read(peer.path().join("v000001/x")).unwrap(),
                   payload);
    }

    #[test]
    fn mid_replicate_fault_fails_only_the_replica_level() {
        let dir = crate::util::TempDir::new("pipe-repfault").unwrap();
        let peer = crate::util::TempDir::new("pipe-repfault-peer").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::single(
            Arc::new(LocalFs::new(dir.path())), tl);
        p.set_replicas(&ReplicaSpec::to_peers(vec![
            peer.path().to_path_buf()
        ]));
        let inj = Arc::new(FaultInjector::new(0));
        inj.arm(KillPoint::MidReplicate);
        p.set_fault_injector(Some(inj.clone()));
        let f = p.create_landing("v000002/x").unwrap();
        f.write_at(0, &vec![7u8; 4096]).unwrap();
        f.finalize().unwrap();
        let s = replica_session(2);
        p.submit_drain(VersionDrainJob {
            session: s.clone(),
            requested: Instant::now(),
            dir: "v000002".into(),
            files: vec!["x".into()],
            notify: None,
        })
        .unwrap();
        let t = crate::CheckpointTicket::new(s.clone());
        let e = t.wait_durable(TierKind::Replicated).unwrap_err();
        assert!(e.to_string().contains("mid-replicate"), "{e:#}");
        assert_eq!(inj.fired(), 1);
        // the local copy is untouched — only the replica level failed
        assert!(dir.path().join("v000002/x").is_file());
        assert!(!t.is_durable(TierKind::Replicated));
    }

    #[test]
    fn from_specs_builds_remote_tier_at_stable_root() {
        let dir = crate::util::TempDir::new("pipe-remote").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::from_specs(
            &[TierSpec::local_fs(),
              TierSpec::remote(0.0).content_chunks(1024)],
            dir.path(),
            false,
            1 << 20,
            None,
            tl.clone(),
        )
        .unwrap();
        assert_eq!(p.tier_kinds(),
                   vec![TierKind::LocalFs, TierKind::Remote]);
        let f = p.terminal().create("v000001/x").unwrap();
        f.write_at(0, b"remote bytes").unwrap();
        f.finalize().unwrap();
        assert!(dir.path().join("remote/objects").is_dir());
        drop(p);

        // a remote-ONLY stack over the same ckpt_dir resolves the same
        // store: the version written above is still readable
        let p2 = TierPipeline::from_specs(
            &[TierSpec::remote(0.0).content_chunks(1024)],
            dir.path(),
            false,
            1 << 20,
            None,
            tl,
        )
        .unwrap();
        let r = p2.terminal().open("v000001/x").unwrap();
        let mut buf = vec![0u8; 12];
        r.read_exact_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"remote bytes");
    }

    #[test]
    fn from_specs_roots_terminal_fs_at_ckpt_dir() {
        let dir = crate::util::TempDir::new("pipe-specs").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::from_specs(
            &[TierSpec::host_cache(), TierSpec::local_fs()],
            dir.path(),
            true,
            1 << 20,
            None,
            tl,
        )
        .unwrap();
        assert!(p.is_multi());
        assert_eq!(p.tier_kinds(),
                   vec![TierKind::HostCache, TierKind::LocalFs]);
        // the terminal tier writes land directly under ckpt_dir
        let f = p.terminal().create("v000001/x").unwrap();
        f.write_at(0, b"z").unwrap();
        f.finalize().unwrap();
        assert!(dir.path().join("v000001/x").is_file());
    }

    #[test]
    fn open_nearest_retries_transient_errors_in_place() {
        // ISSUE 10 satellite: a transient EINTR on the fast tier must
        // retry IN PLACE, not demote the read to the slower tier.
        let a = crate::util::TempDir::new("pipe-near-a").unwrap();
        let b = crate::util::TempDir::new("pipe-near-b").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::new(
            vec![Arc::new(LocalFs::new(a.path())),
                 Arc::new(LocalFs::new(b.path()))],
            false,
            1 << 20,
            tl,
        );
        // DIFFERENT content per tier so the winning tier is observable
        std::fs::create_dir_all(a.path().join("v000001")).unwrap();
        std::fs::create_dir_all(b.path().join("v000001")).unwrap();
        std::fs::write(a.path().join("v000001/x"), b"fast").unwrap();
        std::fs::write(b.path().join("v000001/x"), b"deep").unwrap();

        let calls = std::sync::atomic::AtomicUsize::new(0);
        let got = p
            .open_nearest("v000001/x", |r| {
                use std::sync::atomic::Ordering;
                // the FIRST attempt fails transiently — a retried read
                // must come back to this same (fast) tier
                if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                    return Err(anyhow::Error::from(
                        std::io::Error::from(
                            std::io::ErrorKind::Interrupted,
                        ),
                    ));
                }
                let mut buf = vec![0u8; r.len()? as usize];
                r.read_exact_at(&mut buf, 0)?;
                Ok(String::from_utf8(buf).unwrap())
            })
            .unwrap();
        assert_eq!(got, "fast",
                   "transient error demoted the read to a deeper tier");
        assert_eq!(
            calls.load(std::sync::atomic::Ordering::SeqCst), 2);

        // permanent errors still fall through to the deeper tier
        let calls2 = std::sync::atomic::AtomicUsize::new(0);
        let got = p
            .open_nearest("v000001/x", |r| {
                use std::sync::atomic::Ordering;
                if calls2.fetch_add(1, Ordering::SeqCst) == 0 {
                    anyhow::bail!("trailer magic mismatch");
                }
                let mut buf = vec![0u8; r.len()? as usize];
                r.read_exact_at(&mut buf, 0)?;
                Ok(String::from_utf8(buf).unwrap())
            })
            .unwrap();
        assert_eq!(got, "deep");
    }

    #[test]
    fn quarantined_tier_is_skipped_without_wedging_the_queue() {
        // middle tier permanently broken (its root is a FILE, so every
        // create fails): the first hops fail the version the historical
        // way; once the breaker quarantines the tier, later versions
        // skip the hop, land on the terminal tier, and report the
        // skipped level degraded instead of hanging.
        let broken = crate::util::TempDir::new("pipe-q-b").unwrap();
        let c = crate::util::TempDir::new("pipe-q-c").unwrap();
        let broken_root = broken.path().join("not-a-dir");
        std::fs::write(&broken_root, b"occupied").unwrap();
        let tl = Arc::new(Timeline::new());
        let p = TierPipeline::new(
            vec![Arc::new(HostCache::new()),
                 Arc::new(LocalFs::new(&broken_root)),
                 Arc::new(LocalFs::new(c.path()))],
            false,
            1 << 20,
            tl,
        );
        let submit = |v: u64| {
            let rel = format!("v{v:06}/x");
            let f = p.create_landing(&rel).unwrap();
            f.write_at(0, &vec![v as u8; 2048]).unwrap();
            f.finalize().unwrap();
            let s = CkptSession::new(
                v,
                None,
                Arc::new(crate::metrics::ProgressCounters::default()),
                Default::default(),
                p.tier_kinds(),
            );
            p.submit_drain(VersionDrainJob {
                session: s.clone(),
                requested: Instant::now(),
                dir: format!("v{v:06}"),
                files: vec!["x".into()],
                notify: None,
            })
            .unwrap();
            crate::CheckpointTicket::new(s)
        };
        // three failing hops trip the breaker (QUARANTINE_AFTER = 3)
        for v in 1..=3 {
            let e = submit(v).wait_persisted().unwrap_err();
            assert!(e.to_string().contains("tier drain to"), "{e:#}");
        }
        assert!(p.health().tier(1).is_quarantined());
        assert_eq!(p.health().quarantine_events_total(), 1);
        // the next versions SKIP the quarantined hop: terminal
        // persistence resolves, the skipped level errors by name, the
        // queue never wedges
        for v in 4..=5 {
            let t = submit(v);
            t.wait_persisted().unwrap();
            let e = t.wait_durable(TierKind::LocalFs).unwrap_err();
            assert!(e.to_string().contains("quarantined"), "{e:#}");
            assert!(
                c.path().join(format!("v{v:06}/x")).is_file(),
                "terminal copy must land despite the skipped hop"
            );
        }
        assert_eq!(p.drains_pending(), 0);
        assert!(p.pending_hops() >= 1,
                "skipped hops must queue for recovery");
    }
}
