//! In-tree infrastructure: the build environment is offline (only the
//! vendored `anyhow`/`xla` stand-ins under `rust/vendor/` are
//! available), so channels, codecs, RNG, temp dirs, a micro-benchmark
//! harness, and property-testing helpers are implemented here instead
//! of pulled from crates.io.

pub mod bench;
pub mod channel;
pub mod json;
pub mod codec;
pub mod proptest;
pub mod rng;
pub mod tempdir;

pub use channel::{bounded, unbounded, Receiver, Sender, TryRecvError};
pub use codec::{Decoder, Encoder};
pub use rng::Rng;
pub use tempdir::TempDir;
