//! The shared gather-run read cache of the serving plane: a bounded
//! LRU of SEALED run images keyed by `(pipeline identity, file,
//! extent-run range)`, with **single-flight fill dedup** — when K
//! concurrent restore sessions request the same sealed run, exactly one
//! performs the backing read; the rest block on the fill and scatter
//! out of the shared image.
//!
//! Why runs and not files: the read planner's coalesced gather runs are
//! deterministic for a given (version, layout, engine geometry), so
//! concurrent readers of one checkpoint version request *identical*
//! run keys. Caching at run granularity therefore captures all
//! cross-session reuse while keeping entries bounded (a run is at most
//! `coalesce_bytes`) and never holding a whole checkpoint hostage.
//!
//! Backpressure discipline (deadlock-freedom): fills read into plain
//! heap buffers, never the pinned staging pool, and a run LARGER than
//! the whole cache bypasses caching entirely (counted in
//! [`RunCacheStats::bypasses`]) instead of waiting for space that can
//! never appear. A full cache evicts idle entries; when everything
//! resident is still being filled elsewhere the new image is simply
//! served uncached. No path blocks on cache capacity.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Cache key of one sealed gather run. `ns` is the identity of the
/// source pipeline's shared tier state (`Arc` pointer), so engines and
/// reshard worlds wrapping the same pipeline share entries while
/// distinct pipelines can never collide.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RunKey {
    /// Source-pipeline namespace (shared-state identity).
    pub ns: u64,
    /// Tier-relative file path (e.g. `v000003/rank0_model.ckpt`).
    pub rel: String,
    /// Run start offset in the file.
    pub start: u64,
    /// Run span in bytes (gaps included).
    pub span: u64,
}

struct Entry {
    bytes: Arc<Vec<u8>>,
    /// LRU clock value of the last touch.
    last_used: u64,
}

struct Inner {
    ready: HashMap<RunKey, Entry>,
    /// Keys currently being filled by some thread (single-flight).
    pending: HashSet<RunKey>,
    /// Resident payload bytes across `ready`.
    used: u64,
    /// Monotonic LRU clock.
    tick: u64,
}

/// Counter snapshot of a [`RunCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RunCacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Runs too large for the cache, served uncached.
    pub bypasses: u64,
    pub evictions: u64,
    pub fill_errors: u64,
    pub resident_bytes: u64,
    pub cap_bytes: u64,
    pub entries: usize,
}

impl RunCacheStats {
    /// Fraction of run requests served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Bounded single-flight LRU cache of sealed gather-run images, shared
/// by every [`crate::restore::ReadEngine`] of a
/// [`crate::serve::CheckpointService`].
pub struct RunCache {
    cap: u64,
    inner: Mutex<Inner>,
    cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    bypasses: AtomicU64,
    evictions: AtomicU64,
    fill_errors: AtomicU64,
}

impl RunCache {
    /// A cache bounded at `cap_bytes` of resident run payload.
    pub fn new(cap_bytes: u64) -> Arc<RunCache> {
        Arc::new(RunCache {
            cap: cap_bytes,
            inner: Mutex::new(Inner {
                ready: HashMap::new(),
                pending: HashSet::new(),
                used: 0,
                tick: 0,
            }),
            cv: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            bypasses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            fill_errors: AtomicU64::new(0),
        })
    }

    /// Serve `key`, filling via `fill` on a miss. Returns the run image
    /// and whether it was a hit. Single-flight: concurrent callers of
    /// one missing key block while ONE runs `fill`; on fill failure the
    /// waiters retry as fillers themselves (the failure may be
    /// tier-transient and is re-reported per caller if not).
    pub fn get_or_fill(
        &self,
        key: RunKey,
        fill: impl FnOnce() -> anyhow::Result<Vec<u8>>,
    ) -> anyhow::Result<(Arc<Vec<u8>>, bool)> {
        {
            let mut inner = self.inner.lock().unwrap();
            loop {
                if inner.ready.contains_key(&key) {
                    inner.tick += 1;
                    let tick = inner.tick;
                    let e = inner.ready.get_mut(&key).unwrap();
                    e.last_used = tick;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((e.bytes.clone(), true));
                }
                if inner.pending.contains(&key) {
                    // someone is filling this key: wait, then re-check
                    // (on their failure we fall out and fill ourselves)
                    inner = self.cv.wait(inner).unwrap();
                    continue;
                }
                break;
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            if key.span > self.cap {
                // larger than the whole cache: serve uncached rather
                // than wait for space that cannot exist
                self.bypasses.fetch_add(1, Ordering::Relaxed);
                drop(inner);
                return Ok((Arc::new(fill()?), false));
            }
            inner.pending.insert(key.clone());
        }
        // fill OUTSIDE the lock — concurrent fills of different keys
        // proceed in parallel
        match fill() {
            Ok(buf) => {
                let bytes = Arc::new(buf);
                let mut inner = self.inner.lock().unwrap();
                inner.pending.remove(&key);
                self.insert_evicting(&mut inner, key, bytes.clone());
                self.cv.notify_all();
                Ok((bytes, false))
            }
            Err(e) => {
                self.fill_errors.fetch_add(1, Ordering::Relaxed);
                let mut inner = self.inner.lock().unwrap();
                inner.pending.remove(&key);
                self.cv.notify_all();
                Err(e)
            }
        }
    }

    /// Insert under LRU eviction; if eviction cannot free enough space
    /// the image is simply not cached (callers already hold the bytes).
    fn insert_evicting(&self, inner: &mut Inner, key: RunKey,
                       bytes: Arc<Vec<u8>>) {
        let span = bytes.len() as u64;
        while inner.used + span > self.cap {
            let victim = inner
                .ready
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = inner.ready.remove(&k) {
                        inner.used -= e.bytes.len() as u64;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => return, // empty cache and still no room
            }
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.used += span;
        inner.ready.insert(key, Entry { bytes, last_used: tick });
    }

    /// Drop every resident entry (in-flight fills are unaffected).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.ready.clear();
        inner.used = 0;
    }

    pub fn stats(&self) -> RunCacheStats {
        let inner = self.inner.lock().unwrap();
        RunCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            bypasses: self.bypasses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            fill_errors: self.fill_errors.load(Ordering::Relaxed),
            resident_bytes: inner.used,
            cap_bytes: self.cap,
            entries: inner.ready.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn key(rel: &str, start: u64, span: u64) -> RunKey {
        RunKey { ns: 7, rel: rel.to_string(), start, span }
    }

    #[test]
    fn hit_after_fill_and_stats() {
        let c = RunCache::new(1 << 20);
        let (b1, hit1) = c
            .get_or_fill(key("a", 0, 4), || Ok(vec![1, 2, 3, 4]))
            .unwrap();
        assert!(!hit1);
        let (b2, hit2) = c
            .get_or_fill(key("a", 0, 4), || panic!("must not refill"))
            .unwrap();
        assert!(hit2);
        assert_eq!(b1, b2);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.resident_bytes, 4);
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn single_flight_dedups_concurrent_fills() {
        let c = RunCache::new(1 << 20);
        let fills = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            let fills = fills.clone();
            handles.push(std::thread::spawn(move || {
                c.get_or_fill(key("a", 0, 64), || {
                    fills.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(
                        std::time::Duration::from_millis(20),
                    );
                    Ok(vec![9u8; 64])
                })
                .unwrap()
                .0
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().as_slice(), &[9u8; 64][..]);
        }
        assert_eq!(fills.load(Ordering::SeqCst), 1,
                   "K requests for one run must cost one backing read");
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 8);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lru_evicts_and_oversized_runs_bypass() {
        let c = RunCache::new(100);
        c.get_or_fill(key("a", 0, 60), || Ok(vec![0u8; 60])).unwrap();
        c.get_or_fill(key("b", 0, 60), || Ok(vec![0u8; 60])).unwrap();
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 1);
        assert!(s.resident_bytes <= 100);
        // "a" was evicted: refill is a miss
        let (_, hit) =
            c.get_or_fill(key("a", 0, 60), || Ok(vec![0u8; 60]))
                .unwrap();
        assert!(!hit);
        // larger than the whole cache: served, uncached, no deadlock
        let (big, hit) = c
            .get_or_fill(key("big", 0, 4096), || Ok(vec![7u8; 4096]))
            .unwrap();
        assert!(!hit);
        assert_eq!(big.len(), 4096);
        assert_eq!(c.stats().bypasses, 1);
    }

    #[test]
    fn failed_fill_wakes_waiters_and_retries() {
        let c = RunCache::new(1 << 20);
        assert!(c
            .get_or_fill(key("a", 0, 8), || {
                anyhow::bail!("torn copy")
            })
            .is_err());
        assert_eq!(c.stats().fill_errors, 1);
        // the key is not wedged: the next caller fills it
        let (b, hit) = c
            .get_or_fill(key("a", 0, 8), || Ok(vec![1u8; 8]))
            .unwrap();
        assert!(!hit);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn clear_drops_entries() {
        let c = RunCache::new(1 << 20);
        c.get_or_fill(key("a", 0, 8), || Ok(vec![0u8; 8])).unwrap();
        c.clear();
        let s = c.stats();
        assert_eq!((s.entries, s.resident_bytes), (0, 0));
    }
}
