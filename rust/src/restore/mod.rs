//! Checkpoint restore: parse the hybrid layout, reconstruct state, verify
//! integrity (the recovery half of the paper's consistency story).
//!
//! The low-level view is [`ChunkSource`] (`source.rs`): a read-side
//! chunk stream over the same [`FileLayout`] the write-side providers
//! produced, so restore pipelines mirror checkpoint pipelines.
//! [`read_file`]/[`read_from`] are the SERIAL single-file reference
//! path (one positioned read per extent — the byte oracle the engine is
//! property-tested against); every directory/version-level restore
//! routes through the parallel [`ReadEngine`] (`engine.rs`): coalesced
//! gather reads over a tier-aware reader pool, staged through a pinned
//! pool and multi-lane H2D upload.

pub mod engine;
pub mod reshard;
pub mod source;

pub use engine::{PassReport, ReadEngine, ReadEngineConfig};
pub use reshard::{plan_reshard, restore_for_topology, CheckpointWorld,
                  ReshardPlan};
pub use source::ChunkSource;

use std::collections::HashMap;
use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};

use crate::provider::layout::{EntryKind, FileLayout};
use crate::state::{PyObj, RankState, StateItem, TensorData};

/// A fully parsed checkpoint file.
#[derive(Debug)]
pub struct RestoredFile {
    pub layout: FileLayout,
    /// name -> reassembled payload bytes (tensors and serialized
    /// objects).
    pub payloads: HashMap<String, Vec<u8>>,
}

impl RestoredFile {
    /// Deserialize a restored object entry.
    pub fn object(&self, name: &str) -> anyhow::Result<PyObj> {
        let bytes = self
            .payloads
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no entry {name}"))?;
        PyObj::from_bytes(bytes)
    }
}

/// Read one checkpoint file written by any engine using the hybrid
/// layout: footer → trailer → entries → extents, via the read-side
/// [`ChunkSource`] view.
pub fn read_file(path: &Path) -> anyhow::Result<RestoredFile> {
    read_from(Box::new(File::open(path)?))
        .map_err(|e| anyhow::anyhow!("{path:?}: {e:#}"))
}

/// Read one checkpoint file out of any positioned-read surface — this
/// is how the tier pipeline restores from whichever tier holds the
/// nearest complete copy, including the in-memory host cache.
pub fn read_from(reader: Box<dyn crate::storage::ReadAt>)
    -> anyhow::Result<RestoredFile> {
    let src = ChunkSource::from_reader(reader,
                                       source::DEFAULT_CHUNK_BYTES)?;
    let mut payloads = HashMap::new();
    for (name, bytes) in src.read_all()? {
        payloads.insert(name, bytes);
    }
    Ok(RestoredFile { layout: src.layout().clone(), payloads })
}

/// Verify a restored file set (as produced by
/// `storage::TierPipeline::read_version`) against the original rank
/// state bit-for-bit — the tier-agnostic sibling of [`verify_against`].
pub fn verify_files_against(
    restored: &HashMap<String, RestoredFile>,
    state: &RankState,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        restored.len() == state.files.len(),
        "file count mismatch: {} vs {}",
        restored.len(),
        state.files.len()
    );
    for shard in &state.files {
        let rf = restored
            .get(&shard.name)
            .ok_or_else(|| anyhow::anyhow!("missing file {}", shard.name))?;
        for item in &shard.items {
            match item {
                StateItem::Tensor(t) => {
                    let got = rf.payloads.get(&t.name).ok_or_else(|| {
                        anyhow::anyhow!("missing tensor {}", t.name)
                    })?;
                    // compare against borrowed views: host tensors (the
                    // dominant payload) are checked in place; only
                    // device tensors stage into a scratch buffer
                    let (matches, want_len) = match &t.data {
                        TensorData::Host(b) => {
                            (got.as_slice() == b.as_slice(), b.len())
                        }
                        TensorData::Device(d) => {
                            let mut v = vec![0u8; d.size_bytes()];
                            d.stage_into(&mut v)?;
                            (*got == v, v.len())
                        }
                    };
                    anyhow::ensure!(
                        matches,
                        "tensor {} content mismatch ({} vs {} bytes)",
                        t.name,
                        got.len(),
                        want_len
                    );
                }
                StateItem::Object { name, obj } => {
                    let got = rf.object(name)?;
                    anyhow::ensure!(got == *obj,
                                    "object {name} mismatch");
                }
            }
        }
    }
    Ok(())
}

/// Read every file of a checkpoint version directory, through the
/// parallel [`ReadEngine`] — the ONE directory-level restore read path
/// (`verify_against`, the CLI restore and the train-session resume all
/// funnel here; `read_file` remains the serial per-file oracle).
pub fn read_version_dir(dir: &Path)
    -> anyhow::Result<HashMap<String, RestoredFile>> {
    ReadEngine::new(ReadEngineConfig::default()).read_dir(dir)
}

/// Latest version directory under a checkpoint root (`v000042/`...).
pub fn latest_version(root: &Path) -> anyhow::Result<Option<(u64, PathBuf)>> {
    let mut best: Option<(u64, PathBuf)> = None;
    if !root.exists() {
        return Ok(None);
    }
    for entry in std::fs::read_dir(root)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(v) = name.strip_prefix('v')
            .and_then(|s| s.parse::<u64>().ok())
        {
            if best.as_ref().map(|(b, _)| v > *b).unwrap_or(true) {
                best = Some((v, entry.path()));
            }
        }
    }
    Ok(best)
}

/// Verify that a restored checkpoint version matches the original rank
/// state bit-for-bit (used by tests and the failure_recovery example).
pub fn verify_against(dir: &Path, state: &RankState) -> anyhow::Result<()> {
    verify_files_against(&read_version_dir(dir)?, state)
}

/// Integrity check without reference state: footer magic, trailer parse,
/// extent bounds. Returns the number of entries validated.
pub fn fsck(path: &Path) -> anyhow::Result<usize> {
    let rf = read_file(path)?;
    let file_len = std::fs::metadata(path)?.len();
    for e in &rf.layout.entries {
        for (off, elen) in &e.extents {
            anyhow::ensure!(off + elen <= file_len,
                            "{}: extent beyond EOF", e.name);
        }
        if matches!(e.kind, EntryKind::Object) {
            // objects must deserialize
            rf.object(&e.name)?;
        }
    }
    Ok(rf.layout.entries.len())
}

/// Outcome of a directory-level [`fsck_dir_repair`] pass.
#[derive(Debug, Default, Clone)]
pub struct FsckReport {
    pub files_checked: u64,
    pub files_ok: u64,
    pub files_repaired: u64,
    /// Files that verify on neither the target nor the donor —
    /// `"<name>: <cause>"`.
    pub unrepairable: Vec<String>,
}

/// Verify every checkpoint file of version directory `dir` ([`fsck`]
/// per file); with a `from` donor directory (a deeper tier's copy of
/// the version, a peer replica tree), rebuild each torn or bit-rotted
/// file byte-for-byte from the donor's same-named file — the donor
/// copy is fsck'd FIRST, the rebuild goes through a `.repair.tmp` +
/// rename (no torn repairs), and the rebuilt file is fsck'd again.
/// Without a donor the pass is check-only.
pub fn fsck_dir_repair(dir: &Path, from: Option<&Path>)
    -> anyhow::Result<FsckReport> {
    let mut rep = FsckReport::default();
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if entry.file_type()?.is_file() {
            names.push(entry.file_name().to_string_lossy().into_owned());
        }
    }
    names.sort();
    for name in names {
        rep.files_checked += 1;
        let path = dir.join(&name);
        let err = match fsck(&path) {
            Ok(_) => {
                rep.files_ok += 1;
                continue;
            }
            Err(e) => e,
        };
        let Some(donor_dir) = from else {
            rep.unrepairable.push(format!("{name}: {err:#}"));
            continue;
        };
        let donor = donor_dir.join(&name);
        if let Err(de) = fsck(&donor) {
            rep.unrepairable.push(format!(
                "{name}: {err:#}; donor copy {donor:?}: {de:#}"));
            continue;
        }
        let tmp = dir.join(format!("{name}.repair.tmp"));
        let rebuilt = std::fs::copy(&donor, &tmp)
            .map_err(anyhow::Error::from)
            .and_then(|_| {
                std::fs::rename(&tmp, &path)?;
                fsck(&path)?;
                Ok(())
            });
        match rebuilt {
            Ok(()) => {
                eprintln!("[fsck] {name}: rebuilt from {donor:?} \
                           (was: {err:#})");
                rep.files_repaired += 1;
            }
            Err(re) => {
                let _ = std::fs::remove_file(&tmp);
                rep.unrepairable.push(format!(
                    "{name}: {err:#}; rebuild from {donor:?} \
                     failed: {re:#}"));
            }
        }
    }
    Ok(rep)
}

/// Read one checkpoint file sequentially (used to measure read-side
/// throughput; exercises a different I/O path than `read_file`).
pub fn read_raw(path: &Path) -> anyhow::Result<Vec<u8>> {
    let mut f = File::open(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    Ok(buf)
}

/// Parallel restore of a version directory with an explicit reader
/// count — the restart-path counterpart of the write-side flush pool
/// (restart speed matters as much as checkpoint speed for the
/// resilience scenarios in §I). The ad-hoc one-file-per-worker thread
/// pool this used to spawn is folded into the [`ReadEngine`]: reads are
/// now coalesced into gather runs and balanced across the pool at
/// extent granularity, so one huge file no longer serializes on one
/// worker.
pub fn read_version_dir_parallel(dir: &Path, threads: usize)
    -> anyhow::Result<HashMap<String, RestoredFile>> {
    let cfg = ReadEngineConfig {
        readers: threads.max(1),
        ..Default::default()
    };
    ReadEngine::new(cfg).read_dir(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::engine::CheckpointEngine;
    use crate::state::partition::{census, materialize};
    use crate::config::{LlmConfig, Parallelism};
    use crate::util::TempDir;

    fn write_one(dir: &Path) -> crate::state::RankState {
        let cfg = LlmConfig::by_name("3B").unwrap();
        let par = Parallelism::paper_default(&cfg);
        let cs = census(&cfg, &par);
        let state = materialize(&cs.ranks[0], 2e-5, 0.02, 99);
        let mut eng = crate::engine::DataStatesEngine::new(
            EngineConfig::with_dir(dir)).unwrap();
        let ticket = eng.begin(0, &state).unwrap();
        ticket.wait_persisted().unwrap();
        state
    }

    #[test]
    fn parallel_restore_matches_serial() {
        let dir = TempDir::new("restore-par").unwrap();
        let state = write_one(dir.path());
        let vdir = dir.path().join("v000000");
        let serial = read_version_dir(&vdir).unwrap();
        let parallel = read_version_dir_parallel(&vdir, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (name, rf) in &serial {
            let pf = parallel.get(name).unwrap();
            assert_eq!(rf.payloads, pf.payloads, "{name}");
        }
        verify_against(&vdir, &state).unwrap();
    }

    #[test]
    fn latest_version_picks_max() {
        let dir = TempDir::new("restore-latest").unwrap();
        for v in [1u64, 7, 3] {
            std::fs::create_dir_all(
                dir.path().join(format!("v{v:06}"))).unwrap();
        }
        let (v, _) = latest_version(dir.path()).unwrap().unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn fsck_rejects_truncated_file() {
        let dir = TempDir::new("restore-fsck").unwrap();
        write_one(dir.path());
        let vdir = dir.path().join("v000000");
        let victim = std::fs::read_dir(&vdir).unwrap().next()
            .unwrap().unwrap().path();
        let len = std::fs::metadata(&victim).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true)
            .open(&victim).unwrap();
        f.set_len(len / 2).unwrap();
        assert!(fsck(&victim).is_err());
    }

    #[test]
    fn fsck_repair_rebuilds_torn_copy_byte_identically() {
        let dir = TempDir::new("restore-repair").unwrap();
        let state = write_one(dir.path());
        let vdir = dir.path().join("v000000");
        // pristine donor copy of the version (stands in for the
        // deeper tier / peer replica tree)
        let donor = dir.path().join("donor");
        std::fs::create_dir_all(&donor).unwrap();
        for e in std::fs::read_dir(&vdir).unwrap() {
            let p = e.unwrap().path();
            std::fs::copy(&p, donor.join(p.file_name().unwrap()))
                .unwrap();
        }
        // tear one copy mid-file
        let victim = std::fs::read_dir(&vdir).unwrap().next()
            .unwrap().unwrap().path();
        let len = std::fs::metadata(&victim).unwrap().len();
        std::fs::OpenOptions::new().write(true)
            .open(&victim).unwrap().set_len(len / 2).unwrap();
        // check-only: the tear is found, nothing is touched
        let chk = fsck_dir_repair(&vdir, None).unwrap();
        assert_eq!(chk.files_repaired, 0);
        assert_eq!(chk.unrepairable.len(), 1);
        assert!(fsck(&victim).is_err());
        // repair: rebuilt from the donor, byte-identical
        let rep = fsck_dir_repair(&vdir, Some(&donor)).unwrap();
        assert_eq!(rep.files_repaired, 1);
        assert!(rep.unrepairable.is_empty(), "{:?}", rep.unrepairable);
        verify_against(&vdir, &state).unwrap();
        // idempotent: a second pass finds everything healthy
        let again = fsck_dir_repair(&vdir, Some(&donor)).unwrap();
        assert_eq!(again.files_repaired, 0);
        assert_eq!(again.files_ok, again.files_checked);
    }
}
