//! # DataStates-LLM (reproduction)
//!
//! A scalable checkpointing runtime for transformer training using
//! **composable state providers**, reproducing
//! *DataStates-LLM: Scalable Checkpointing for Transformer Models Using
//! Composable State Providers* (CS.DC 2026).
//!
//! The crate is organized as the Layer-3 coordinator of a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`):
//!
//! - [`state`] — the checkpoint payload model: tensor shards, Python-like
//!   control objects, and the 3D (TP/PP/DP + ZeRO-1) partitioner that
//!   reproduces the paper's "3D checkpoint heterogeneity" (Table I).
//! - [`provider`] — the paper's core contribution: the
//!   [`provider::StateProvider`] chunk-stream abstraction (readiness-
//!   driven via [`provider::Notifier`]), zero-copy tensor providers,
//!   lazily-serializing object providers, hierarchical composition, and
//!   the hybrid fixed-offset / log-append file layout.
//! - [`engine`] — the data-movement engine: pinned host pool, D2H staging
//!   stream, multi-threaded flush pool, and per-version checkpoint
//!   sessions — [`engine::CheckpointEngine::begin`] returns a
//!   [`engine::CheckpointTicket`] owning that version's lazy-capture
//!   consistency gate, persistence future, progress, and metrics.
//! - [`storage`] — the persistence plane as composable tiers: the
//!   [`storage::Backend`] trait over real filesystems and the in-memory
//!   host cache, per-tier bandwidth throttles, and the
//!   [`storage::TierPipeline`] that lands checkpoints on the fastest
//!   tier, drains them tier-to-tier in the background (per-tier
//!   durability futures on the ticket), and resolves restores from the
//!   nearest complete copy via a cross-tier manifest. The terminal hop
//!   can be a content-addressed remote tier ([`storage::content`]):
//!   files dedupe into checksum-keyed chunks so each checkpoint
//!   uploads only what training dirtied, behind a simulated-WAN
//!   latency/bandwidth shim.
//! - [`baselines`] — faithful re-implementations of the compared engines:
//!   DeepSpeed-default (`torch.save`-style), TorchSnapshot-like, and
//!   DataStates-LLM-Old (HPDC'24).
//! - [`train`] — the training orchestrator: iteration phases with
//!   immutability windows, real PJRT-backed steps and analytic phase
//!   models.
//! - [`runtime`] — PJRT wrapper: loads the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py` and keeps training state
//!   device-resident between steps.
//! - [`cluster`] + [`sim`] — a calibrated discrete-event model of the
//!   Polaris testbed used to regenerate the paper-scale figures.
//! - [`restore`] — checkpoint parsing, verification, resume, and
//!   restore-time resharding: [`restore::reshard::restore_for_topology`]
//!   materializes any rank of any topology from the logical state index
//!   ([`state::index::LogicalIndex`]) built from the self-describing
//!   trailers. Every directory/version-level read runs on the parallel
//!   gather-read engine ([`restore::ReadEngine`]): coalesced vectored
//!   reads over a tier-aware reader pool, staged through a pinned pool
//!   and multi-lane H2D upload.
//! - [`serve`] — checkpoint serving at scale: the
//!   [`serve::CheckpointService`] shares one tier pipeline per source
//!   rank across many concurrent restore/reshard/verify sessions, with
//!   admission control, weighted QoS throttle charging, a
//!   single-flight gather-run read cache ([`serve::RunCache`]) and
//!   persistent per-class read engines.
//! - [`faults`] — deterministic failure injection: seeded kill points
//!   (mid-capture, mid-drain, mid-replicate, mid-restore), torn files
//!   on every tier and whole-node loss, driving the `figures faults`
//!   recovery matrix against the peer-replication layer
//!   ([`storage::ReplicaSpec`]).
//! - [`metrics`] — throughput/blocked-time accounting and the per-tensor
//!   multi-tier timelines of Fig 15.
//! - [`harness`] — one driver per paper table/figure.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod engine;
pub mod faults;
pub mod harness;
pub mod metrics;
pub mod provider;
pub mod restore;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod state;
pub mod storage;
pub mod train;
pub mod util;

pub use engine::checkpoint::{CheckpointEngine, DataStatesEngine};
pub use engine::ticket::CheckpointTicket;
pub use provider::StateProvider;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
