//! Background serializer pool.
//!
//! Object serialization happens OFF the critical path, on worker threads,
//! so that it overlaps with bulk tensor I/O (§V-A5). State-of-the-art
//! engines do the opposite — serialize metadata first, blocking, to
//! precompute the persistent layout; the hybrid layout (layout.rs) is
//! what removes that ordering constraint.
//!
//! Workers participate in the readiness protocol: a submission may carry
//! the engine's [`Notifier`], signalled after the serialized bytes are
//! published so the pump wakes and drains the now-ready object stream,
//! and a [`ProgressCounters`] handle so checkpoint tickets can report
//! live per-version serialization progress.

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::channel::{Receiver, Sender};

use super::notify::Notifier;
use crate::metrics::ProgressCounters;
use crate::state::object::PyObj;

enum Job {
    Serialize {
        name: String,
        obj: PyObj,
        out: Sender<Vec<u8>>,
        notify: Option<Arc<Notifier>>,
        progress: Option<Arc<ProgressCounters>>,
    },
    Stop,
}

/// A pool of serialization workers shared by all object providers of a
/// rank.
pub struct SerializerPool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
}

impl SerializerPool {
    pub fn new(threads: usize) -> Arc<Self> {
        Self::with_timeline(threads, None)
    }

    /// Build with an optional timeline to record `Tier::Serialize` spans
    /// (used by the engine for Table III attribution).
    pub fn with_timeline(
        threads: usize,
        timeline: Option<Arc<crate::metrics::Timeline>>,
    ) -> Arc<Self> {
        let (tx, rx) = crate::util::channel::unbounded::<Job>();
        let rx = Arc::new(rx);
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx: Arc<Receiver<Job>> = rx.clone();
                let tl = timeline.clone();
                std::thread::Builder::new()
                    .name(format!("ds-serializer-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            match job {
                                Job::Serialize {
                                    name,
                                    obj,
                                    out,
                                    notify,
                                    progress,
                                } => {
                                    let start =
                                        tl.as_ref().map(|t| t.now_s());
                                    let bytes = obj.to_bytes();
                                    if let (Some(t), Some(s)) =
                                        (tl.as_ref(), start)
                                    {
                                        t.record(
                                            crate::metrics::Tier::Serialize,
                                            &name,
                                            bytes.len() as u64,
                                            s,
                                            t.now_s(),
                                        );
                                    }
                                    if let Some(p) = &progress {
                                        p.add_serialized(
                                            bytes.len() as u64);
                                    }
                                    // Receiver may be gone if the
                                    // checkpoint was aborted; ignore.
                                    let _ = out.send(bytes);
                                    // Publish-then-signal: the bytes are
                                    // on the channel before the pump is
                                    // woken.
                                    if let Some(n) = &notify {
                                        n.notify();
                                    }
                                }
                                Job::Stop => break,
                            }
                        }
                    })
                    .expect("spawn serializer")
            })
            .collect();
        Arc::new(SerializerPool { tx, workers })
    }

    /// Submit an object; its serialized bytes arrive on the returned
    /// channel.
    pub fn submit(&self, obj: PyObj) -> Receiver<Vec<u8>> {
        self.submit_named(String::new(), obj)
    }

    /// Submit with a name for timeline attribution.
    pub fn submit_named(&self, name: String, obj: PyObj)
        -> Receiver<Vec<u8>> {
        self.submit_streamed(name, obj, None, None)
    }

    /// Submit into a readiness-driven stream: `notify` is signalled after
    /// the bytes are published; `progress` receives the serialized byte
    /// count for the owning checkpoint session.
    pub fn submit_streamed(
        &self,
        name: String,
        obj: PyObj,
        notify: Option<Arc<Notifier>>,
        progress: Option<Arc<ProgressCounters>>,
    ) -> Receiver<Vec<u8>> {
        let (out_tx, out_rx) = crate::util::channel::bounded(1);
        self.tx
            .send(Job::Serialize {
                name,
                obj,
                out: out_tx,
                notify,
                progress,
            })
            .expect("serializer pool alive");
        out_rx
    }
}

impl Drop for SerializerPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_in_background() {
        let pool = SerializerPool::new(2);
        let obj = PyObj::synthetic_metadata(4096, 1);
        let want = obj.to_bytes();
        let rx = pool.submit(obj);
        let got = rx.recv().unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn many_concurrent_jobs() {
        let pool = SerializerPool::new(4);
        let rxs: Vec<_> = (0..32)
            .map(|i| pool.submit(PyObj::synthetic_metadata(1024, i)))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let bytes = rx.recv().unwrap();
            assert_eq!(bytes,
                       PyObj::synthetic_metadata(1024, i as u64).to_bytes());
        }
    }

    #[test]
    fn streamed_submit_signals_notifier_after_publish() {
        let pool = SerializerPool::new(1);
        let notifier = Notifier::new();
        let progress = Arc::new(ProgressCounters::default());
        let seen = notifier.epoch();
        let obj = PyObj::synthetic_metadata(2048, 9);
        let want = obj.to_bytes();
        let rx = pool.submit_streamed("meta".into(), obj,
                                      Some(notifier.clone()),
                                      Some(progress.clone()));
        notifier.wait_past(seen);
        // after the signal, the bytes MUST already be available
        let got = rx.try_recv().expect("bytes published before signal");
        assert_eq!(got, want);
        assert_eq!(progress.snapshot().bytes_serialized,
                   want.len() as u64);
    }
}
