//! Multi-producer multi-consumer channel (std has only MPSC).
//!
//! Semantics follow the familiar crossbeam API subset used by the engine:
//! cloneable `Sender`/`Receiver`, blocking `recv`, non-blocking
//! `try_recv`, disconnect detection when all senders drop.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    queue: Mutex<Inner<T>>,
    /// consumers wait here (queue empty)
    not_empty: Condvar,
    /// bounded producers wait here (queue full)
    not_full: Condvar,
}

struct Inner<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
    capacity: Option<usize>,
}

pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

impl std::fmt::Display for TryRecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TryRecvError::Empty => write!(f, "channel empty"),
            TryRecvError::Disconnected => write!(f, "channel disconnected"),
        }
    }
}

impl std::error::Error for TryRecvError {}

#[derive(PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> std::fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SendError(..)")
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.senders -= 1;
        if q.senders == 0 {
            drop(q);
            // disconnect: wake every blocked consumer
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.receivers -= 1;
        if q.receivers == 0 {
            drop(q);
            // disconnect: wake every blocked producer
            self.shared.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Send, blocking while a bounded channel is full. Errors if all
    /// receivers dropped.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if q.receivers == 0 {
                return Err(SendError(item));
            }
            match q.capacity {
                Some(cap) if q.items.len() >= cap => {
                    q = self.shared.not_full.wait(q).unwrap();
                }
                _ => break,
            }
        }
        q.items.push_back(item);
        drop(q);
        self.shared.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; errors when empty and all senders dropped.
    pub fn recv(&self) -> Result<T, TryRecvError> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                let bounded = q.capacity.is_some();
                drop(q);
                if bounded {
                    self.shared.not_full.notify_one();
                }
                return Ok(item);
            }
            if q.senders == 0 {
                return Err(TryRecvError::Disconnected);
            }
            q = self.shared.not_empty.wait(q).unwrap();
        }
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(item) = q.items.pop_front() {
            let bounded = q.capacity.is_some();
            drop(q);
            if bounded {
                self.shared.not_full.notify_one();
            }
            return Ok(item);
        }
        if q.senders == 0 {
            Err(TryRecvError::Disconnected)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn channel<T>(capacity: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(Inner {
            items: VecDeque::new(),
            senders: 1,
            receivers: 1,
            capacity,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

/// Unbounded MPMC channel.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    channel(None)
}

/// Bounded MPMC channel (senders block when full).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    channel(Some(capacity.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let (tx, rx) = unbounded();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(rx.recv().unwrap(), i);
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }

    #[test]
    fn disconnect_on_sender_drop() {
        let (tx, rx) = unbounded::<u32>();
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn send_fails_without_receivers() {
        let (tx, rx) = unbounded::<u32>();
        drop(rx);
        assert!(tx.send(5).is_err());
    }

    #[test]
    fn mpmc_workers_share_queue() {
        let (tx, rx) = unbounded::<u32>();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || {
                let mut n = 0u32;
                while rx.recv().is_ok() {
                    n += 1;
                }
                n
            }));
        }
        drop(rx);
        for i in 0..1000 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn bounded_blocks_until_consumed() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(3).unwrap(); // blocks until a slot frees
            std::time::Instant::now()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        let before = std::time::Instant::now();
        assert_eq!(rx.recv().unwrap(), 1);
        let sent_at = t.join().unwrap();
        assert!(sent_at >= before);
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }
}
