"""L1 kernel correctness: Pallas vs pure-jnp oracle (pytest + hypothesis).

The hypothesis sweeps exercise the Pallas kernels across shapes/dtypes and
assert allclose against ref.py — the CORE correctness signal for Layer 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adam as adam_kernel
from compile.kernels import attention as attn_kernel
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("b,h,t,dh", [
    (1, 1, 32, 16),
    (2, 4, 64, 32),
    (1, 2, 128, 64),
    (3, 2, 64, 16),
])
def test_attention_matches_ref(b, h, t, dh):
    q, k, v = (rand(i, (b, h, t, dh)) for i in range(3))
    out = attn_kernel.attention(q, k, v, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_attention_noncausal_matches_ref():
    q, k, v = (rand(i, (2, 2, 64, 32)) for i in range(3))
    out = attn_kernel.attention(q, k, v, causal=False, block_q=32,
                                block_k=32)
    expect = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_attention_causality():
    """Output at position i must not depend on keys/values after i."""
    q, k, v = (rand(i, (1, 1, 64, 16)) for i in range(3))
    out1 = attn_kernel.attention(q, k, v, block_q=32, block_k=32)
    # perturb the tail of k/v; the first half of the output must not move
    k2 = k.at[:, :, 48:, :].set(rand(9, (1, 1, 16, 16)))
    v2 = v.at[:, :, 48:, :].set(rand(10, (1, 1, 16, 16)))
    out2 = attn_kernel.attention(q, k2, v2, block_q=32, block_k=32)
    np.testing.assert_allclose(out1[:, :, :48], out2[:, :, :48],
                               atol=1e-6, rtol=1e-6)


def test_attention_bf16():
    q, k, v = (rand(i, (1, 2, 64, 32), jnp.bfloat16) for i in range(3))
    out = attn_kernel.attention(q, k, v, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=3e-2, rtol=3e-2,
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t_blocks=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 32, 64]),
    block=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_hypothesis_sweep(b, h, t_blocks, dh, block, seed):
    t = t_blocks * block
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, t, dh))
    k = jax.random.normal(kk, (b, h, t, dh))
    v = jax.random.normal(kv, (b, h, t, dh))
    out = attn_kernel.attention(q, k, v, block_q=block, block_k=block)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, atol=5e-5, rtol=5e-5)


def test_attention_vmem_estimate_positive():
    est = attn_kernel.vmem_footprint_bytes(64, 64, 2048, 64)
    assert 0 < est < 16 * 1024 * 1024  # fits in one core's VMEM


# -------------------------------------------------------------------- adam

@pytest.mark.parametrize("n,block", [(1024, 256), (4096, 1024),
                                     (16384, 16384)])
def test_adam_matches_ref(n, block):
    p, g = rand(0, (n,)), rand(1, (n,))
    m, v = rand(2, (n,)) * 0.1, jnp.abs(rand(3, (n,))) * 0.01
    got = adam_kernel.adam_update(p, m, v, g, jnp.float32(5.0), block=block)
    want = ref.adam_ref(p, m, v, g, 5.0)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 8),
    block=st.sampled_from([128, 512, 1024]),
    step=st.integers(1, 10_000),
    seed=st.integers(0, 2**16),
)
def test_adam_hypothesis_sweep(blocks, block, step, seed):
    n = blocks * block
    key = jax.random.PRNGKey(seed)
    kp, km, kv_, kg = jax.random.split(key, 4)
    p = jax.random.normal(kp, (n,))
    m = jax.random.normal(km, (n,)) * 0.1
    v = jnp.abs(jax.random.normal(kv_, (n,))) * 0.01
    g = jax.random.normal(kg, (n,))
    got = adam_kernel.adam_update(p, m, v, g, jnp.float32(step),
                                  block=block)
    want = ref.adam_ref(p, m, v, g, float(step))
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=2e-6, rtol=2e-6)


def test_adam_moves_against_gradient():
    p = jnp.zeros((512,))
    g = jnp.ones((512,))
    pn, _, _ = adam_kernel.adam_update(p, jnp.zeros_like(p),
                                       jnp.zeros_like(p), g,
                                       jnp.float32(1.0), block=512)
    assert bool(jnp.all(pn < 0))  # step against +grad
