//! The hybrid fixed-offset / log-structured-append checkpoint file layout
//! (paper §V-A5).
//!
//! ```text
//! ┌────────────────────────────────────────────────────────────┐
//! │ tensor region: offsets PRECOMPUTED from known tensor sizes │
//! ├────────────────────────────────────────────────────────────┤
//! │ log region: serialized-object chunks, CONCURRENT APPEND    │
//! │   (sizes unknown a priori; offsets claimed from a cursor)  │
//! ├────────────────────────────────────────────────────────────┤
//! │ trailer: encoded FileLayout (names, kinds, offsets, sizes) │
//! ├────────────────────────────────────────────────────────────┤
//! │ footer: trailer_offset u64 | trailer_len u64 | MAGIC u64   │
//! └────────────────────────────────────────────────────────────┘
//! ```
//!
//! Tensors are written at fixed offsets *while* objects are still being
//! serialized; object chunks land wherever the log cursor was when their
//! bytes became available. The trailer — written last — is what makes the
//! file self-describing, so metadata construction never blocks bulk I/O
//! (the inversion of the state-of-the-art order that §V-A5 describes).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::state::tensor::{DType, LogicalRef};
use crate::util::codec::{Decoder, Encoder};

// Format version 002: trailer entries carry a per-entry `LogicalRef`
// tag. Bumped from "DSLLM001" so pre-logical-ref checkpoints fail with
// a clear magic mismatch instead of a misleading "bad logical tag" /
// "trailing bytes" decode error that restore would treat as a torn
// copy.
pub const MAGIC: u64 = 0x4453_4C4C_4D30_3032; // "DSLLM002"
pub const FOOTER_BYTES: u64 = 24;

/// What one layout entry describes.
#[derive(Debug, Clone, PartialEq)]
pub enum EntryKind {
    Tensor { dtype: DType, shape: Vec<usize> },
    /// A serialized object; may span several log chunks, recorded in
    /// order.
    Object,
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F16 => 0,
        DType::BF16 => 1,
        DType::F32 => 2,
        DType::I32 => 3,
        DType::U8 => 4,
    }
}

fn dtype_from_tag(t: u8) -> anyhow::Result<DType> {
    Ok(match t {
        0 => DType::F16,
        1 => DType::BF16,
        2 => DType::F32,
        3 => DType::I32,
        4 => DType::U8,
        _ => anyhow::bail!("bad dtype tag {t}"),
    })
}

/// One logical object in the file.
#[derive(Debug, Clone, PartialEq)]
pub struct LayoutEntry {
    pub name: String,
    pub kind: EntryKind,
    /// (offset, len) extents, in logical order. Tensors have exactly one
    /// extent in the fixed region; objects may have several in the log
    /// region (concurrent append interleaves producers).
    pub extents: Vec<(u64, u64)>,
    /// Which slice of which *logical* tensor this entry holds — recorded
    /// in the trailer so a checkpoint stays resharddable without the
    /// topology that wrote it (`state::index`, `restore::reshard`).
    /// `None` for rank-local state (objects, metadata tensors).
    pub logical: Option<LogicalRef>,
}

impl LayoutEntry {
    pub fn total_len(&self) -> u64 {
        self.extents.iter().map(|(_, l)| l).sum()
    }
}

/// The self-describing trailer of one checkpoint file.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FileLayout {
    pub file_name: String,
    /// Bytes in the fixed (tensor) region.
    pub fixed_region: u64,
    pub entries: Vec<LayoutEntry>,
}

impl FileLayout {
    pub fn encode_trailer(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        e.str(&self.file_name).u64(self.fixed_region)
            .u64(self.entries.len() as u64);
        for entry in &self.entries {
            e.str(&entry.name);
            match &entry.kind {
                EntryKind::Tensor { dtype, shape } => {
                    e.u8(0).u8(dtype_tag(*dtype))
                        .u64(shape.len() as u64);
                    for &s in shape {
                        e.u64(s as u64);
                    }
                }
                EntryKind::Object => {
                    e.u8(1);
                }
            }
            e.u64(entry.extents.len() as u64);
            for (off, len) in &entry.extents {
                e.u64(*off).u64(*len);
            }
            match &entry.logical {
                Some(l) => {
                    e.u8(1).str(l.tensor.as_str())
                        .u64(l.range.start).u64(l.range.end);
                }
                None => {
                    e.u8(0);
                }
            }
        }
        e.finish()
    }

    pub fn decode_trailer(bytes: &[u8]) -> anyhow::Result<FileLayout> {
        let mut d = Decoder::new(bytes);
        let file_name = d.str()?;
        let fixed_region = d.u64()?;
        let n_entries = d.u64()? as usize;
        anyhow::ensure!(n_entries <= bytes.len(), "entry count too big");
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let name = d.str()?;
            let kind = match d.u8()? {
                0 => {
                    let dtype = dtype_from_tag(d.u8()?)?;
                    let ndim = d.u64()? as usize;
                    anyhow::ensure!(ndim <= 16, "too many dims");
                    let mut shape = Vec::with_capacity(ndim);
                    for _ in 0..ndim {
                        shape.push(d.u64()? as usize);
                    }
                    EntryKind::Tensor { dtype, shape }
                }
                1 => EntryKind::Object,
                t => anyhow::bail!("bad entry kind {t}"),
            };
            let n_ext = d.u64()? as usize;
            anyhow::ensure!(n_ext <= bytes.len(), "extent count too big");
            let mut extents = Vec::with_capacity(n_ext);
            for _ in 0..n_ext {
                extents.push((d.u64()?, d.u64()?));
            }
            let logical = match d.u8()? {
                0 => None,
                1 => {
                    let tensor = d.str()?;
                    let (start, end) = (d.u64()?, d.u64()?);
                    anyhow::ensure!(start <= end,
                                    "bad logical range {start}..{end}");
                    Some(LogicalRef::new(tensor, start..end))
                }
                t => anyhow::bail!("bad logical tag {t}"),
            };
            entries.push(LayoutEntry { name, kind, extents, logical });
        }
        anyhow::ensure!(d.done(), "trailing bytes in trailer");
        Ok(FileLayout { file_name, fixed_region, entries })
    }

    /// Encode the 24-byte footer.
    pub fn encode_footer(trailer_offset: u64, trailer_len: u64) -> [u8; 24] {
        let mut f = [0u8; 24];
        f[0..8].copy_from_slice(&trailer_offset.to_le_bytes());
        f[8..16].copy_from_slice(&trailer_len.to_le_bytes());
        f[16..24].copy_from_slice(&MAGIC.to_le_bytes());
        f
    }

    /// Parse a footer; returns (trailer_offset, trailer_len).
    pub fn decode_footer(f: &[u8]) -> anyhow::Result<(u64, u64)> {
        anyhow::ensure!(f.len() == 24, "footer must be 24 bytes");
        let magic = u64::from_le_bytes(f[16..24].try_into()?);
        anyhow::ensure!(magic == MAGIC, "bad magic {magic:#x}");
        Ok((
            u64::from_le_bytes(f[0..8].try_into()?),
            u64::from_le_bytes(f[8..16].try_into()?),
        ))
    }
}

/// Concurrent log-region cursor: producers claim disjoint extents with a
/// single atomic add (the "concurrent-log-structured append" of §V-A5).
#[derive(Debug)]
pub struct LogCursor {
    next: AtomicU64,
}

impl LogCursor {
    /// Starts at the end of the fixed tensor region.
    pub fn new(fixed_region_end: u64) -> Self {
        LogCursor { next: AtomicU64::new(fixed_region_end) }
    }

    /// Claim `len` bytes; returns the extent's start offset.
    pub fn claim(&self, len: u64) -> u64 {
        self.next.fetch_add(len, Ordering::Relaxed)
    }

    /// Current end of the log region.
    pub fn end(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }
}

/// Plan the fixed region: assign offsets to known-size tensors.
/// Returns (offsets aligned to `align`, end of fixed region).
pub fn plan_fixed_region(sizes: &[u64], align: u64) -> (Vec<u64>, u64) {
    let mut offsets = Vec::with_capacity(sizes.len());
    let mut cur = 0u64;
    for &s in sizes {
        cur = cur.div_ceil(align) * align;
        offsets.push(cur);
        cur += s;
    }
    (offsets, cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trailer_roundtrip() {
        let l = FileLayout {
            file_name: "layer_00.pt".into(),
            fixed_region: 4096,
            entries: vec![
                LayoutEntry {
                    name: "w".into(),
                    kind: EntryKind::Tensor {
                        dtype: DType::F16,
                        shape: vec![64, 32],
                    },
                    extents: vec![(0, 4096)],
                    logical: Some(LogicalRef::new("unit002/t0",
                                                  4096..8192)),
                },
                LayoutEntry {
                    name: "meta".into(),
                    kind: EntryKind::Object,
                    extents: vec![(4096, 100)],
                    logical: None,
                },
            ],
        };
        let t = l.encode_trailer();
        let got = FileLayout::decode_trailer(&t).unwrap();
        assert_eq!(got, l);
        let lr = got.entries[0].logical.as_ref().unwrap();
        assert_eq!(lr.tensor.as_str(), "unit002/t0");
        assert_eq!(lr.range, 4096..8192);
    }

    #[test]
    fn footer_roundtrip() {
        let f = FileLayout::encode_footer(123, 456);
        assert_eq!(FileLayout::decode_footer(&f).unwrap(), (123, 456));
        let mut bad = f;
        bad[20] ^= 0xFF;
        assert!(FileLayout::decode_footer(&bad).is_err());
    }

    #[test]
    fn fixed_region_is_disjoint_and_aligned() {
        let (offs, end) = plan_fixed_region(&[100, 200, 50], 64);
        assert_eq!(offs, vec![0, 128, 384]);
        assert_eq!(end, 434);
        for w in offs.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn log_cursor_claims_disjoint() {
        let c = LogCursor::new(1000);
        let a = c.claim(10);
        let b = c.claim(20);
        let d = c.claim(5);
        assert_eq!((a, b, d), (1000, 1010, 1030));
        assert_eq!(c.end(), 1035);
    }
}
