//! DataStates-LLM-Old baseline: the authors' HPDC'24 engine (§VI-B3).
//!
//! Shares the *lazy* half of the design with the new engine — pinned-pool
//! D2H staging overlapped with forward/backward, consistency gate before
//! the update (the ticket's `wait_captured`) — but keeps the
//! state-of-the-art ordering the new engine removes:
//!
//! - **metadata-first**: all non-tensor objects are serialized INLINE on
//!   the critical path at request time (to precompute the persistent
//!   layout up front),
//! - **snapshot-then-flush per file**: a file's flush begins only after
//!   every tensor of that file has been staged (no chunk streaming), and
//! - **single background writer**: files are persisted one at a time.
//!
//! The deltas to `DataStatesEngine` are exactly the paper's §V-A3/§V-A5
//! contributions, making this pair an ablation of the state-provider
//! design.

use std::sync::Arc;
use std::time::Instant;

use crate::config::EngineConfig;
use crate::engine::pool::PinnedPool;
use crate::engine::stager::{SnapshotTracker, StageJob, Stager};
use crate::engine::ticket::{CheckpointTicket, CkptSession};
use crate::engine::CheckpointEngine;
use crate::metrics::{CkptMetrics, ProgressCounters, Tier, Timeline};
use crate::provider::layout::{plan_fixed_region, EntryKind, FileLayout,
                              LayoutEntry};
use crate::provider::Bytes;
use crate::state::{RankState, StateItem, TensorData};
use super::common::single_tier_pipeline;
use crate::storage::{Backend, BackendFile, TierPipeline};
use crate::util::channel::{unbounded, Receiver, Sender};

/// One file's flush work: staged tensor bytes (await on channels) and the
/// pre-serialized objects.
struct FileTask {
    name: String,
    fixed_region: u64,
    /// (entry, base offset, channel with staged bytes)
    tensors: Vec<(LayoutEntry, u64, Receiver<Bytes>)>,
    /// (entry with final extents, serialized bytes)
    objects: Vec<(LayoutEntry, Vec<u8>)>,
}

struct FlushTask {
    session: Arc<CkptSession>,
    /// Version directory, tier-relative (`"v000042"`).
    dir: String,
    files: Vec<FileTask>,
    requested: Instant,
}

enum WorkerMsg {
    Task(FlushTask),
    Stop,
}

pub struct DataStatesOldEngine {
    timeline: Arc<Timeline>,
    pipeline: Arc<TierPipeline>,
    stager: Stager,
    flush_tx: Sender<WorkerMsg>,
    worker: Option<std::thread::JoinHandle<()>>,
    sessions: Vec<Arc<CkptSession>>,
}

impl DataStatesOldEngine {
    pub fn new(cfg: EngineConfig) -> anyhow::Result<Self> {
        std::fs::create_dir_all(&cfg.ckpt_dir)?;
        let timeline = Arc::new(Timeline::new());
        let pipeline = single_tier_pipeline("datastates-old", &cfg,
                                            timeline.clone());
        let pool = PinnedPool::new(cfg.host_cache_bytes);
        let stager = Stager::new(pool, timeline.clone());
        let (flush_tx, flush_rx) = unbounded::<WorkerMsg>();
        let tl = timeline.clone();
        let worker_pipeline = pipeline.clone();
        // single background writer: files persisted one at a time
        let worker = std::thread::Builder::new()
            .name("ds-old-flush".into())
            .spawn(move || {
                while let Ok(WorkerMsg::Task(task)) = flush_rx.recv() {
                    match Self::flush_task(&task, &tl, &worker_pipeline) {
                        Ok(()) => {
                            let names: Vec<String> = task
                                .files
                                .iter()
                                .map(|f| f.name.clone())
                                .collect();
                            worker_pipeline.record_terminal_complete(
                                task.session.version(), &names);
                            task.session.complete(
                                task.requested.elapsed().as_secs_f64());
                        }
                        Err(e) => {
                            eprintln!(
                                "[datastates-old] flush v{} failed: {e:#}",
                                task.session.version()
                            );
                            task.session.fail(format!("{e:#}"));
                        }
                    }
                }
            })
            .expect("spawn ds-old-flush");
        Ok(DataStatesOldEngine {
            timeline,
            pipeline,
            stager,
            flush_tx,
            worker: Some(worker),
            sessions: Vec::new(),
        })
    }

    fn flush_task(task: &FlushTask, tl: &Timeline,
                  pipeline: &TierPipeline) -> anyhow::Result<()> {
        let backend = pipeline.terminal();
        let progress = task.session.progress_counters();
        for file in &task.files {
            // snapshot-then-flush: wait for ALL tensors of this file
            let mut staged = Vec::with_capacity(file.tensors.len());
            for (entry, base, rx) in &file.tensors {
                let bytes = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("stager dropped"))?;
                staged.push((entry.clone(), *base, bytes));
            }
            // whole-file sequential write (no positioned parallelism)
            let start = tl.now_s();
            let f = backend
                .create(&format!("{}/{}", task.dir, file.name))?;
            let mut entries = Vec::new();
            let mut buf: Vec<u8> = Vec::new();
            for (entry, base, bytes) in &staged {
                if buf.len() < (*base as usize + bytes.len()) {
                    buf.resize(*base as usize + bytes.len(), 0);
                }
                buf[*base as usize..*base as usize + bytes.len()]
                    .copy_from_slice(bytes.as_slice());
                entries.push(entry.clone());
            }
            buf.resize(buf.len().max(file.fixed_region as usize), 0);
            let mut log_off = file.fixed_region;
            for (entry, bytes) in &file.objects {
                let mut e = entry.clone();
                e.extents = vec![(log_off, bytes.len() as u64)];
                log_off += bytes.len() as u64;
                buf.extend_from_slice(bytes);
                entries.push(e);
            }
            f.write_at(0, &buf)?;
            progress.add_flushed(buf.len() as u64);
            let layout = FileLayout {
                file_name: file.name.clone(),
                fixed_region: file.fixed_region,
                entries,
            };
            let trailer = layout.encode_trailer();
            f.write_at(buf.len() as u64, &trailer)?;
            f.write_at(
                buf.len() as u64 + trailer.len() as u64,
                &FileLayout::encode_footer(log_off, trailer.len() as u64),
            )?;
            f.finalize()?;
            tl.record(Tier::H2F, &file.name, buf.len() as u64, start,
                      tl.now_s());
        }
        Ok(())
    }
}

impl CheckpointEngine for DataStatesOldEngine {
    fn name(&self) -> &'static str {
        "datastates-old"
    }

    fn begin(&mut self, version: u64, state: &RankState)
        -> anyhow::Result<CheckpointTicket> {
        let t0 = Instant::now();
        let progress = Arc::new(ProgressCounters::default());
        let n_device: usize = state
            .files
            .iter()
            .flat_map(|f| f.items.iter())
            .filter(|i| matches!(i, StateItem::Tensor(t)
                                 if t.data.is_device()))
            .count();
        let tracker = SnapshotTracker::new(n_device);
        let mut files = Vec::with_capacity(state.files.len());
        for file in &state.files {
            let tensor_sizes: Vec<u64> = file
                .items
                .iter()
                .filter_map(|i| match i {
                    StateItem::Tensor(t) => Some(t.size_bytes() as u64),
                    _ => None,
                })
                .collect();
            let (offsets, fixed_end) = plan_fixed_region(&tensor_sizes, 64);
            let mut tensors = Vec::new();
            let mut objects = Vec::new();
            let mut ti = 0usize;
            for item in &file.items {
                match item {
                    StateItem::Tensor(t) => {
                        let base = offsets[ti];
                        ti += 1;
                        let entry = LayoutEntry {
                            name: t.name.clone(),
                            kind: EntryKind::Tensor {
                                dtype: t.dtype,
                                shape: t.shape.clone(),
                            },
                            extents: vec![(base,
                                           t.size_bytes() as u64)],
                            logical: t.logical.clone(),
                        };
                        let (tx, rx) = crate::util::channel::bounded(1);
                        match &t.data {
                            TensorData::Device(dev) => {
                                // lazy D2H, same as the new engine
                                self.stager.submit(StageJob {
                                    name: t.name.clone(),
                                    tensor: dev.clone(),
                                    out: tx,
                                    tracker: tracker.clone(),
                                    notify: None,
                                    progress: Some(progress.clone()),
                                });
                            }
                            TensorData::Host(b) => {
                                let _ = tx.send(Bytes::from_arc(b.clone()));
                            }
                        }
                        tensors.push((entry, base, rx));
                    }
                    StateItem::Object { name, obj } => {
                        // METADATA-FIRST: serialize inline, blocking —
                        // the ordering the new engine's providers remove
                        let start = self.timeline.now_s();
                        let bytes = obj.to_bytes();
                        self.timeline.record(Tier::Serialize, name,
                                             bytes.len() as u64, start,
                                             self.timeline.now_s());
                        progress.add_serialized(bytes.len() as u64);
                        objects.push((
                            LayoutEntry {
                                name: name.clone(),
                                kind: EntryKind::Object,
                                extents: Vec::new(),
                                logical: None,
                            },
                            bytes,
                        ));
                    }
                }
            }
            files.push(FileTask {
                name: file.name.clone(),
                fixed_region: fixed_end,
                tensors,
                objects,
            });
        }
        let total: u64 = state.total_bytes() as u64;
        progress.add_total(total);
        let session = CkptSession::new(
            version,
            Some(tracker),
            progress,
            CkptMetrics {
                version,
                blocked_s: t0.elapsed().as_secs_f64(),
                bytes: total,
                ..Default::default()
            },
            self.pipeline.tier_kinds(),
        );
        self.flush_tx
            .send(WorkerMsg::Task(FlushTask {
                session: session.clone(),
                dir: format!("v{version:06}"),
                files,
                requested: t0,
            }))
            .map_err(|_| anyhow::anyhow!("flush worker dead"))?;
        self.sessions.push(session.clone());
        Ok(CheckpointTicket::new(session))
    }

    fn metrics(&self) -> Vec<CkptMetrics> {
        self.sessions.iter().map(|s| s.metrics()).collect()
    }

    fn timeline(&self) -> Arc<Timeline> {
        self.timeline.clone()
    }

    fn pipeline(&self) -> Arc<TierPipeline> {
        self.pipeline.clone()
    }
}

impl Drop for DataStatesOldEngine {
    fn drop(&mut self) {
        // explicit stop: queued tasks drain first (FIFO)
        let _ = self.flush_tx.send(WorkerMsg::Stop);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::shard::FileKind;
    use crate::state::tensor::{DType, SimDeviceTensor, TensorShard};
    use crate::state::{PyObj, ShardFile};
    use crate::util::TempDir;

    #[test]
    fn lazy_capture_then_restore_roundtrip() {
        let dir = TempDir::new("ds-old").unwrap();
        let mut eng = DataStatesOldEngine::new(
            EngineConfig::with_dir(dir.path())).unwrap();
        let payload: Vec<u8> = (0..4096u32).map(|i| i as u8).collect();
        let state = RankState {
            rank: 0,
            files: vec![ShardFile {
                name: "layer_00.pt".into(),
                kind: FileKind::ParamLayer,
                items: vec![
                    StateItem::Tensor(TensorShard::device(
                        "w", DType::U8, vec![4096],
                        SimDeviceTensor::new(payload.clone()))),
                    StateItem::Object {
                        name: "meta".into(),
                        obj: PyObj::synthetic_metadata(300, 5),
                    },
                ],
            }],
        };
        let ticket = eng.begin(0, &state).unwrap();
        let waited = ticket.wait_captured().unwrap();
        assert!(waited >= 0.0);
        ticket.wait_persisted().unwrap();
        crate::restore::verify_against(&dir.path().join("v000000"),
                                       &state)
            .unwrap();
        // metadata-first: serializer time charged on the critical path
        let (ser_bytes, _) = eng.timeline().tier_summary(Tier::Serialize);
        assert!(ser_bytes > 0);
    }
}
