//! Composable state providers (paper §V-A3) — the core contribution.
//!
//! A [`StateProvider`] sits between the training runtime and the data
//! movement engine. It encapsulates *per-data-structure* knowledge —
//! residency, layout, (de)serialization needs — and presents a uniform
//! stream-oriented view: a sequence of [`Chunk`]s, each "N bytes that
//! belong at offset O of the checkpoint file". The engine stays agnostic
//! to 3D heterogeneity and simply drains competing chunk streams.
//!
//! Streams are **readiness-driven**: pulling the next chunk never
//! blocks. When a stream reports [`ChunkEvent::Blocked`], its bytes are
//! still in flight on an asynchronous producer (the D2H copy stream or
//! the serializer pool); that producer signals the engine's shared
//! [`Notifier`] the moment bytes land, so the consumer parks instead of
//! sleep-polling (see `notify.rs`).
//!
//! The implementations mirror the paper:
//!
//! - [`tensor_provider::TensorProvider`] — zero-copy memory views over
//!   host-resident tensors (no serialization at all, §IV-D),
//! - [`tensor_provider::StagedTensorProvider`] — device tensors whose
//!   bytes arrive asynchronously from the D2H copy stream,
//! - [`object_provider::ObjectProvider`] — Python-like object graphs
//!   serialized *lazily on a worker pool*, claiming log-region extents as
//!   bytes materialize,
//! - [`composite::CompositeProvider`] — hierarchical merge producing one
//!   stream per file, tensors naturally first (§V-A5 overlap).

pub mod bytes;
pub mod composite;
pub mod compress;
pub mod delta;
pub mod layout;
pub mod notify;
pub mod object_provider;
pub mod serializer;
pub mod tensor_provider;

pub use bytes::Bytes;
pub use composite::CompositeProvider;
pub use layout::{FileLayout, LayoutEntry, LogCursor};
pub use notify::Notifier;
pub use object_provider::ObjectProvider;
pub use serializer::SerializerPool;
pub use tensor_provider::{StagedTensorProvider, TensorProvider};

/// One unit of I/O: bytes destined for a file offset.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Absolute offset within the checkpoint file.
    pub offset: u64,
    pub data: Bytes,
    /// Originating object, for the Fig 15 timeline.
    pub label: String,
}

/// What a provider stream yields when asked for its next chunk.
pub enum ChunkEvent {
    /// A chunk is ready for I/O.
    Ready(Chunk),
    /// More chunks will arrive later (D2H or serialization in flight).
    /// The producing side signals the engine's [`Notifier`] when they
    /// materialize — the consumer should drain other streams and park on
    /// the notifier rather than spin, which is exactly the freedom the
    /// engine uses to overlap serialization with bulk I/O.
    Blocked,
    /// Stream exhausted; layout entries are final.
    Exhausted,
}

/// A stream-oriented producer of checkpoint chunks.
pub trait StateProvider: Send {
    /// Best-known total payload size (exact for tensors; an estimate for
    /// not-yet-serialized objects). Used for scheduling hints only.
    fn size_hint(&self) -> u64;

    /// Pull the next chunk. Never blocks: returns
    /// [`ChunkEvent::Blocked`] when bytes are still in flight.
    fn next_chunk(&mut self) -> anyhow::Result<ChunkEvent>;

    /// Layout entries for the trailer. Only complete after
    /// [`ChunkEvent::Exhausted`].
    fn layout_entries(&self) -> Vec<LayoutEntry>;

    /// True once the provider has returned [`ChunkEvent::Exhausted`].
    fn is_done(&self) -> bool;
}
