//! Host→landing-tier flush pool (paper §V-A4, §V-B).
//!
//! Multi-threaded positioned writes drain the chunk queue produced by the
//! state providers. The paper uses liburing + O_DIRECT; the structural
//! equivalents here are a writer-thread pool issuing `pwrite`-style
//! `write_at` calls at provider-assigned offsets (no seeking, no shared
//! file cursor, writers never contend on position). A [`WriteJob`] is a
//! **gather list**: the coalescer's merged runs arrive as extent lists
//! of refcounted chunk views and go to the backend as one vectored
//! write (`write_gather_at`) — no merge buffer, zero payload memcpy
//! between the staging pool and storage. Each file tracks outstanding
//! chunks so finalization (trailer + footer + fsync) runs exactly once,
//! after the last payload byte landed.
//!
//! Files are tier-agnostic: a [`FlushFile`] wraps a
//! [`storage::BackendFile`], so the same pool lands chunks on a real
//! filesystem or on the in-memory host-cache tier — the engine's
//! [`storage::TierPipeline`] decides where, and drains deeper
//! asynchronously.
//!
//! [`storage::BackendFile`]: crate::storage::BackendFile
//! [`storage::TierPipeline`]: crate::storage::TierPipeline

use std::path::Path;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::channel::{Receiver, Sender};
use std::sync::{Condvar, Mutex};

use crate::metrics::{Tier, Timeline};
use crate::provider::layout::FileLayout;
use crate::provider::Bytes;
use crate::storage::{BackendFile, GatherSubmit, IoDone};

/// Chunk accounting of one open file: a single mutex covers the issue
/// and completion counters, so quiescence waits are a plain condvar loop
/// with no timed-wait workaround — the completing writer bumps `written`
/// and notifies UNDER the same lock the waiter sleeps on, making lost
/// wake-ups impossible.
struct FlushState {
    /// Chunks handed to the pool.
    issued: u64,
    /// Chunks whose `write_at` completed.
    written: u64,
    /// No more payload chunks will be issued.
    done_issuing: bool,
    err: Option<String>,
}

/// An open checkpoint file accepting concurrent positioned writes.
pub struct FlushFile {
    pub name: String,
    file: Box<dyn BackendFile>,
    state: Mutex<FlushState>,
    cv: Condvar,
}

impl FlushFile {
    /// Wrap a file created on some storage tier.
    pub fn on_backend(file: Box<dyn BackendFile>, name: impl Into<String>)
        -> Arc<Self> {
        Arc::new(FlushFile {
            name: name.into(),
            file,
            state: Mutex::new(FlushState {
                issued: 0,
                written: 0,
                done_issuing: false,
                err: None,
            }),
            cv: Condvar::new(),
        })
    }

    /// Create a filesystem-backed flush file directly (tests, baselines
    /// that bypass a pipeline).
    pub fn create(path: &Path, name: impl Into<String>)
        -> anyhow::Result<Arc<Self>> {
        let dir = path
            .parent()
            .ok_or_else(|| anyhow::anyhow!("{path:?}: no parent"))?;
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("{path:?}: no file name"))?
            .to_string_lossy()
            .into_owned();
        let backend = crate::storage::LocalFs::new(dir);
        use crate::storage::Backend;
        Ok(Self::on_backend(backend.create(&file_name)?, name))
    }

    fn record_written(&self) {
        let mut st = self.state.lock().unwrap();
        st.written += 1;
        drop(st);
        self.cv.notify_all();
    }

    fn record_error(&self, e: String) {
        let mut st = self.state.lock().unwrap();
        if st.err.is_none() {
            st.err = Some(e);
        }
        drop(st);
        self.cv.notify_all();
    }

    fn record_issued(&self) {
        self.state.lock().unwrap().issued += 1;
    }

    /// Mark that no more payload chunks will be issued for this file.
    pub fn finish_issuing(&self) {
        self.state.lock().unwrap().done_issuing = true;
        self.cv.notify_all();
    }

    /// Non-blocking quiescence check: true once `finish_issuing` was
    /// called and every issued chunk has been written. Used by the
    /// event-driven pump, which parks on the engine notifier (signalled
    /// by the writers per completed chunk) instead of blocking here.
    pub fn is_quiescent(&self) -> anyhow::Result<bool> {
        let st = self.state.lock().unwrap();
        if let Some(e) = &st.err {
            anyhow::bail!("flush {} failed: {e}", self.name);
        }
        Ok(st.done_issuing && st.written == st.issued)
    }

    /// Wait until every issued chunk has been written. Race-free: all
    /// counter updates and this wait share one mutex, so the final
    /// writer's notify can never slip between the check and the sleep.
    pub fn wait_quiescent(&self) -> anyhow::Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(e) = &st.err {
                anyhow::bail!("flush {} failed: {e}", self.name);
            }
            if st.done_issuing && st.written == st.issued {
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Make the raw payload durable on its tier without a trailer
    /// (e.g. TorchSnapshot chunk files).
    pub fn sync(&self) -> anyhow::Result<()> {
        self.file.finalize()
    }

    /// Write the trailer + footer and make the file durable on its tier
    /// — self-describing from here on. Must be called after
    /// `wait_quiescent`.
    pub fn finalize(&self, layout: &FileLayout, log_end: u64) -> anyhow::Result<u64> {
        let trailer = layout.encode_trailer();
        let trailer_off = log_end.max(layout.fixed_region);
        self.file.write_at(trailer_off, &trailer)?;
        let footer =
            FileLayout::encode_footer(trailer_off, trailer.len() as u64);
        self.file.write_at(trailer_off + trailer.len() as u64, &footer)?;
        self.file.finalize()?;
        Ok(trailer_off + trailer.len() as u64 + footer.len() as u64)
    }
}

/// One queued write: a gather list of extents landing back-to-back at
/// `offset`. The engine's coalescer seals a merged run as its extent
/// list — refcounted [`Bytes`] views of pool segments / heap buffers —
/// so the payload is never concatenated in host memory; the storage
/// backend receives the list as one vectored write
/// ([`crate::storage::BackendFile::write_gather_at`]). A single-extent
/// job is the plain positioned write.
pub struct WriteJob {
    pub file: Arc<FlushFile>,
    pub offset: u64,
    /// File-contiguous extents, in file order.
    pub extents: Vec<Bytes>,
    pub label: String,
    /// Readiness signal fired after the write is recorded, so a parked
    /// pump wakes to finalize files whose last chunk just landed.
    pub notify: Option<Arc<crate::provider::Notifier>>,
    /// Per-version progress counters of the owning checkpoint session.
    pub progress: Option<Arc<crate::metrics::ProgressCounters>>,
}

impl WriteJob {
    /// A plain single-extent write with no session attribution
    /// (baselines, tests).
    pub fn plain(file: Arc<FlushFile>, offset: u64, data: Bytes,
                 label: impl Into<String>) -> WriteJob {
        WriteJob {
            file,
            offset,
            extents: vec![data],
            label: label.into(),
            notify: None,
            progress: None,
        }
    }

    /// Total payload bytes across the gather list.
    pub fn total_len(&self) -> u64 {
        self.extents.iter().map(|b| b.len() as u64).sum()
    }
}

enum Msg {
    Job(WriteJob),
    Stop,
}

/// Shared health hooks of the flush pool: the transient-fault retry
/// budget applied around the blocking gather writes, plus the optional
/// landing-tier fault-injection hooks of the `figures flaky` matrix.
struct FlushHooks {
    policy: crate::storage::RetryPolicy,
    injector:
        Option<(Arc<crate::faults::FaultInjector>, &'static str)>,
}

/// The writer-thread pool, shared across checkpoints of a rank.
pub struct FlushPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    hooks: Arc<Mutex<FlushHooks>>,
}

impl FlushPool {
    pub fn new(threads: usize, timeline: Arc<Timeline>) -> Arc<Self> {
        let (tx, rx) = crate::util::channel::unbounded::<Msg>();
        let rx = Arc::new(rx);
        let hooks = Arc::new(Mutex::new(FlushHooks {
            policy: crate::storage::RetryPolicy::default(),
            injector: None,
        }));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx: Arc<Receiver<Msg>> = rx.clone();
                let tl = timeline.clone();
                let hooks = hooks.clone();
                std::thread::Builder::new()
                    .name(format!("ds-flush-{i}"))
                    .spawn(move || {
                        // One completion path for both transports: the
                        // `done` closure below fires either inline
                        // after the blocking gather write, or from the
                        // io_uring completion reaper — the worker is a
                        // submitter, not a blocker, whenever the
                        // backend has a ring.
                        while let Ok(Msg::Job(job)) = rx.recv() {
                            let WriteJob {
                                file,
                                offset,
                                extents,
                                label,
                                notify,
                                progress,
                            } = job;
                            let len: u64 = extents
                                .iter()
                                .map(|b| b.len() as u64)
                                .sum();
                            let start = tl.now_s();
                            let done: IoDone = {
                                let tl = tl.clone();
                                let file = file.clone();
                                Box::new(move |r| match r {
                                    Ok(()) => {
                                        tl.record(
                                            Tier::H2F,
                                            &label,
                                            len,
                                            start,
                                            tl.now_s(),
                                        );
                                        if let Some(p) = &progress {
                                            p.add_flushed(len);
                                        }
                                        file.record_written();
                                        if let Some(n) = &notify {
                                            n.notify();
                                        }
                                    }
                                    Err(e) => {
                                        file.record_error(
                                            e.to_string());
                                        if let Some(n) = &notify {
                                            n.notify();
                                        }
                                    }
                                })
                            };
                            match file.file.submit_write_gather_at(
                                offset, extents, done,
                            ) {
                                GatherSubmit::Submitted => {}
                                GatherSubmit::Blocking(
                                    extents, done) => {
                                    let slices: Vec<&[u8]> = extents
                                        .iter()
                                        .map(|b| b.as_slice())
                                        .collect();
                                    // positioned writes are idempotent
                                    // (same offset, same bytes), so a
                                    // transient fault retries in place
                                    // under the pool's policy (the
                                    // ring path surfaces its errors
                                    // through the reaper as before)
                                    let (hk_policy, hk_inj) = {
                                        let h = hooks.lock().unwrap();
                                        (h.policy.clone(),
                                         h.injector.clone())
                                    };
                                    let key =
                                        crate::storage::health::fnv1a(
                                            file.name.as_bytes())
                                            ^ offset;
                                    let (res, _retries) = hk_policy
                                        .run(key, || {
                                        if let Some((inj, label)) =
                                            &hk_inj
                                        {
                                            let d = inj
                                                .slow_delay_s(label);
                                            if d > 0.0 {
                                                std::thread::sleep(
                                                    std::time::Duration
                                                    ::from_secs_f64(d));
                                            }
                                            if let Some(e) = inj
                                                .transient_error(
                                                    "flush write",
                                                    label)
                                            {
                                                return Err(e);
                                            }
                                        }
                                        file.file.write_gather_at(
                                            offset, &slices)
                                    });
                                    done(res);
                                }
                            }
                        }
                    })
                    .expect("spawn flusher")
            })
            .collect();
        Arc::new(FlushPool { tx, workers, hooks })
    }

    /// Enqueue a chunk write. The file's issued counter is bumped here so
    /// quiescence detection can never observe written > issued.
    pub fn submit(&self, job: WriteJob) {
        job.file.record_issued();
        self.tx.send(Msg::Job(job)).expect("flush pool alive");
    }

    /// Install the transient-fault retry budget applied around the
    /// pool's blocking writes (the `--retry-max` knob).
    pub fn set_retry_policy(&self,
                            policy: crate::storage::RetryPolicy) {
        self.hooks.lock().unwrap().policy = policy;
    }

    /// Arm the landing-tier fault-injection hooks (seeded transient
    /// write faults + slow-tier stalls) on the pool's blocking writes.
    pub fn set_fault_injector(
        &self,
        inj: Option<Arc<crate::faults::FaultInjector>>,
        tier_label: &'static str,
    ) {
        self.hooks.lock().unwrap().injector =
            inj.map(|i| (i, tier_label));
    }
}

impl Drop for FlushPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provider::layout::{EntryKind, LayoutEntry};
    use crate::state::tensor::DType;

    #[test]
    fn concurrent_disjoint_writes_then_finalize() {
        let dir = crate::util::TempDir::new("ds-test").unwrap();
        let path = dir.path().join("f.ds");
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(4, tl);
        let file = FlushFile::create(&path, "f.ds").unwrap();

        let n = 64;
        let chunk = 1024;
        for i in 0..n {
            pool.submit(WriteJob::plain(
                file.clone(),
                (i * chunk) as u64,
                Bytes::from_vec(vec![i as u8; chunk]),
                format!("c{i}"),
            ));
        }
        file.finish_issuing();
        file.wait_quiescent().unwrap();

        let layout = FileLayout {
            file_name: "f.ds".into(),
            fixed_region: (n * chunk) as u64,
            entries: vec![LayoutEntry {
                name: "t".into(),
                kind: EntryKind::Tensor {
                    dtype: DType::U8,
                    shape: vec![n * chunk],
                },
                extents: vec![(0, (n * chunk) as u64)],
                logical: None,
            }],
        };
        file.finalize(&layout, (n * chunk) as u64).unwrap();

        let bytes = std::fs::read(&path).unwrap();
        for i in 0..n {
            assert!(bytes[i * chunk..(i + 1) * chunk]
                .iter()
                .all(|&b| b == i as u8));
        }
        // footer parses back
        let (toff, tlen) =
            FileLayout::decode_footer(&bytes[bytes.len() - 24..]).unwrap();
        let got = FileLayout::decode_trailer(
            &bytes[toff as usize..(toff + tlen) as usize],
        )
        .unwrap();
        assert_eq!(got, layout);
    }

    #[test]
    fn quiescence_requires_finish_issuing() {
        let dir = crate::util::TempDir::new("ds-test").unwrap();
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(2, tl);
        let file = FlushFile::create(&dir.path().join("g.ds"), "g").unwrap();
        pool.submit(WriteJob::plain(file.clone(), 0,
                                    Bytes::from_vec(vec![7; 128]), "x"));
        let f2 = file.clone();
        let h = std::thread::spawn(move || f2.wait_quiescent());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!h.is_finished(), "must wait for finish_issuing");
        assert!(!file.is_quiescent().unwrap(),
                "not quiescent before finish_issuing");
        file.finish_issuing();
        h.join().unwrap().unwrap();
        assert!(file.is_quiescent().unwrap());
    }

    #[test]
    fn writers_signal_notifier_per_completed_chunk() {
        let dir = crate::util::TempDir::new("ds-test").unwrap();
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(2, tl);
        let file =
            FlushFile::create(&dir.path().join("n.ds"), "n").unwrap();
        let notifier = crate::provider::Notifier::new();
        let progress =
            Arc::new(crate::metrics::ProgressCounters::default());
        let seen = notifier.epoch();
        pool.submit(WriteJob {
            file: file.clone(),
            offset: 0,
            extents: vec![Bytes::from_vec(vec![1; 256])],
            label: "c".into(),
            notify: Some(notifier.clone()),
            progress: Some(progress.clone()),
        });
        file.finish_issuing();
        notifier.wait_past(seen);
        // signal arrives only after the write was recorded
        assert!(file.is_quiescent().unwrap());
        assert_eq!(progress.snapshot().bytes_flushed, 256);
    }

    #[test]
    fn gather_job_lands_extents_contiguously() {
        let dir = crate::util::TempDir::new("ds-gather").unwrap();
        let path = dir.path().join("g.ds");
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(2, tl);
        let file = FlushFile::create(&path, "g.ds").unwrap();
        let progress =
            Arc::new(crate::metrics::ProgressCounters::default());
        pool.submit(WriteJob {
            file: file.clone(),
            offset: 100,
            extents: vec![
                Bytes::from_vec(vec![1u8; 10]),
                Bytes::from_vec(vec![2u8; 20]),
                Bytes::from_vec(vec![3u8; 5]),
            ],
            label: "g".into(),
            notify: None,
            progress: Some(progress.clone()),
        });
        file.finish_issuing();
        file.wait_quiescent().unwrap();
        file.sync().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 135);
        assert!(bytes[100..110].iter().all(|&b| b == 1));
        assert!(bytes[110..130].iter().all(|&b| b == 2));
        assert!(bytes[130..135].iter().all(|&b| b == 3));
        // progress was charged the TOTAL gathered bytes, once
        assert_eq!(progress.snapshot().bytes_flushed, 35);
    }

    #[test]
    fn flush_lands_on_host_cache_tier() {
        use crate::storage::{Backend, HostCache, ReadAt};
        let hc = HostCache::new();
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(2, tl);
        let file = FlushFile::on_backend(
            hc.create("v000001/m.ds").unwrap(), "m.ds");
        for i in 0..4u64 {
            pool.submit(WriteJob::plain(
                file.clone(),
                i * 64,
                Bytes::from_vec(vec![i as u8; 64]),
                format!("c{i}"),
            ));
        }
        file.finish_issuing();
        file.wait_quiescent().unwrap();
        let r = hc.open("v000001/m.ds").unwrap();
        assert_eq!(r.len().unwrap(), 256);
        let mut buf = [0u8; 64];
        r.read_exact_at(&mut buf, 192).unwrap();
        assert!(buf.iter().all(|&b| b == 3));
    }

    /// Regression for the old timed-wait workaround: hammer the
    /// completion path; a lost final wake-up would hang this test.
    #[test]
    fn wait_quiescent_never_misses_the_final_notify() {
        let dir = crate::util::TempDir::new("ds-race").unwrap();
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(4, tl);
        for round in 0..50 {
            let file = FlushFile::create(
                &dir.path().join(format!("r{round}.ds")),
                format!("r{round}"),
            )
            .unwrap();
            for i in 0..8u64 {
                pool.submit(WriteJob::plain(
                    file.clone(),
                    i * 16,
                    Bytes::from_vec(vec![round as u8; 16]),
                    "c",
                ));
            }
            file.finish_issuing();
            file.wait_quiescent().unwrap();
        }
    }
}
