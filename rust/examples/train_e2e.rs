//! End-to-end validation: train the ~91M-parameter transformer (AOT
//! compiled from JAX, executed via PJRT — Python is not on this path)
//! with per-interval DataStates-LLM checkpoints, and log the loss curve.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_e2e -- [steps] [interval]
//! ```
//!
//! The full 200-step run recorded in EXPERIMENTS.md used
//! `datastates train --steps 200 --interval 20`.

use datastates::baselines::EngineKind;
use datastates::config::EngineConfig;
use datastates::metrics::{human_bps, human_bytes};
use datastates::runtime::TrainSession;
use datastates::train::TrainLoop;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let interval: u64 =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let artifacts = std::path::Path::new("artifacts");
    println!("compiling AOT artifacts from {artifacts:?} ...");
    let mut session = TrainSession::new(artifacts, 42)?;
    println!(
        "transformer: {:.1}M params, d_model={}, layers={}, batch={}, \
         seq={}",
        session.manifest.num_params as f64 / 1e6,
        session.manifest.d_model,
        session.manifest.n_layers,
        session.manifest.batch,
        session.manifest.seq_len,
    );

    let ckpt_dir = std::env::temp_dir().join("datastates-train-e2e");
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let mut cfg = EngineConfig::with_dir(&ckpt_dir);
    cfg.host_cache_bytes = 1400 << 20; // one full ~1.1 GB snapshot
    let mut engine = EngineKind::DataStatesLlm.build(cfg)?;

    let mut curve: Vec<(u64, f32)> = Vec::new();
    {
        let session_cell = std::cell::RefCell::new(&mut session);
        let curve_cell = std::cell::RefCell::new(&mut curve);
        let mut tl = TrainLoop::new(engine.as_mut(), interval);
        let report = tl.run(
            steps,
            |it| {
                let mut s = session_cell.borrow_mut();
                let tokens = s.sample_tokens(it);
                let loss = s.step(&tokens)?;
                curve_cell.borrow_mut().push((it + 1, loss));
                println!("iter {:>4}  loss {loss:.4}", it + 1);
                Ok(Some(loss))
            },
            |_| Ok(()), // Adam update is fused into the AOT train_step
            |_| Ok(session_cell.borrow_mut().checkpoint_state()),
        )?;
        println!(
            "\n{} iters in {:.1}s ({:.2}s/iter), {} checkpoints, gate \
             wait {:.3}s",
            steps,
            report.wall_s,
            report.mean_iteration_s(),
            report.checkpoints,
            report.total_gate_wait_s()
        );
    }
    session.gc();

    for m in engine.metrics().iter() {
        println!(
            "ckpt v{}: {} blocked {:.4}s persist {:.2}s eff {}",
            m.version,
            human_bytes(m.bytes as f64),
            m.blocked_s,
            m.persist_s,
            human_bps(m.effective_bps())
        );
    }

    // write the loss curve for EXPERIMENTS.md
    let mut csv = String::from("iter,loss\n");
    for (it, loss) in &curve {
        csv.push_str(&format!("{it},{loss}\n"));
    }
    std::fs::write("loss_curve.csv", &csv)?;
    println!("\nloss curve written to loss_curve.csv");
    if curve.len() >= 2 {
        let first = curve[0].1;
        let last = curve[curve.len() - 1].1;
        println!("loss: {first:.4} -> {last:.4} ({})",
                 if last < first { "decreasing ✓" } else { "check run" });
    }
    Ok(())
}
