//! Engine micro-benchmarks: the hot paths of the DataStates pipeline in
//! isolation, used by the §Perf pass (pool allocation, provider
//! chunking, serializer, writer scaling).
//!
//! Run: `cargo bench --bench engine_micro`

use std::sync::Arc;

use datastates::engine::flush::{FlushFile, FlushPool, WriteJob};
use datastates::engine::pool::PinnedPool;
use datastates::metrics::{human_bps, Timeline};
use datastates::provider::layout::LogCursor;
use datastates::provider::{
    Bytes, ChunkEvent, CompositeProvider, ObjectProvider, SerializerPool,
    StateProvider, TensorProvider,
};
use datastates::state::tensor::DType;
use datastates::state::PyObj;
use datastates::util::bench::{black_box, report, report_bps, Bencher};
use datastates::util::TempDir;

fn bench_pool() {
    let b = Bencher::quick();
    let pool = PinnedPool::new(64 << 20);
    let r = b.run("pool: 1024 alloc/free cycles (64KB)", || {
        let mut segs = Vec::with_capacity(64);
        for _ in 0..16 {
            for _ in 0..64 {
                segs.push(pool.try_alloc(64 << 10).unwrap());
            }
            segs.clear();
        }
    });
    report(&r);
}

fn bench_provider_chunking() {
    let b = Bencher::quick();
    let data = Bytes::from_vec(vec![1u8; 256 << 20]);
    for chunk in [256 << 10, 4 << 20, 64 << 20] {
        let r = b.run(
            &format!("tensor provider drain, chunk={}KB", chunk >> 10),
            || {
                let mut p = TensorProvider::new(
                    "t", DType::U8, vec![data.len()], data.clone(), 0,
                    chunk);
                let mut n = 0usize;
                while let ChunkEvent::Ready(c) = p.next_chunk().unwrap() {
                    n += c.data.len();
                }
                black_box(n)
            },
        );
        report_bps(&r, (256u64) << 20);
    }
}

fn bench_serializer() {
    let b = Bencher::quick();
    let obj = PyObj::synthetic_metadata(5 << 20, 3);
    let bytes = obj.to_bytes().len() as u64;
    let r = b.run("serialize 5MB metadata object", || {
        black_box(obj.to_bytes().len())
    });
    report_bps(&r, bytes);

    let pool = SerializerPool::new(2);
    let objs: Vec<PyObj> = (0..16)
        .map(|i| PyObj::synthetic_metadata(64 << 10, i))
        .collect();
    let r = b.run("serializer pool: 16 x 64KB objects", || {
        let rxs: Vec<_> =
            objs.iter().map(|o| pool.submit(o.clone())).collect();
        for rx in rxs {
            black_box(rx.recv().unwrap().len());
        }
    });
    report(&r);
}

fn bench_writers() {
    let b = Bencher { warmup: 1, min_iters: 3, max_iters: 6,
                      budget: std::time::Duration::from_secs(6) };
    let payload = Bytes::from_vec(vec![7u8; 64 << 20]);
    for threads in [1usize, 2, 4, 8] {
        let dir = TempDir::new("em-writers").unwrap();
        let tl = Arc::new(Timeline::new());
        let pool = FlushPool::new(threads, tl);
        let r = b.run(&format!("flush 64MB, {threads} writers"), || {
            let f = FlushFile::create(&dir.join("w.bin"), "w").unwrap();
            for (i, c) in payload.chunks(4 << 20).into_iter().enumerate()
            {
                pool.submit(WriteJob::plain(
                    f.clone(),
                    (i * (4 << 20)) as u64,
                    c,
                    "w",
                ));
            }
            f.finish_issuing();
            f.wait_quiescent().unwrap();
            f.sync().unwrap();
        });
        report_bps(&r, 64 << 20);
    }
}

fn bench_composite_overlap() {
    let b = Bencher::quick();
    let r = b.run("composite: 8 tensors + 4 lazy objects drain", || {
        let cursor = Arc::new(LogCursor::new(8 * (1 << 20)));
        let ser = SerializerPool::new(2);
        let mut children: Vec<Box<dyn StateProvider>> = Vec::new();
        for i in 0..8 {
            children.push(Box::new(TensorProvider::new(
                format!("t{i}"),
                DType::U8,
                vec![1 << 20],
                Bytes::from_vec(vec![i as u8; 1 << 20]),
                (i as u64) << 20,
                256 << 10,
            )));
        }
        for i in 0..4 {
            let rx =
                ser.submit(PyObj::synthetic_metadata(32 << 10, i));
            children.push(Box::new(ObjectProvider::new(
                format!("o{i}"), 32 << 10, rx, cursor.clone(),
                256 << 10)));
        }
        let mut comp = CompositeProvider::new("f", 8 << 20, children);
        let mut total = 0usize;
        loop {
            match comp.next_chunk().unwrap() {
                ChunkEvent::Ready(c) => total += c.data.len(),
                ChunkEvent::Exhausted => break,
                ChunkEvent::Blocked => std::hint::spin_loop(),
            }
        }
        black_box(total)
    });
    report_bps(&r, 8 << 20);
}

fn main() {
    println!("# engine micro-benchmarks (§Perf)");
    bench_pool();
    bench_provider_chunking();
    bench_serializer();
    bench_writers();
    bench_composite_overlap();
}
