//! Paper-scale simulation plane: a calibrated per-rank timeline model of
//! the four checkpoint engines on the Polaris testbed.
//!
//! The real-plane engines (`engine/`, `baselines/`) execute actual bytes
//! on this machine; 70B-over-256-GPUs experiments obviously cannot. This
//! module reproduces the paper's *figures* by simulating each engine's
//! schedule — the same phase structure, gating rules, cache backpressure,
//! and bandwidth sharing, with constants taken from the paper itself
//! (§VI-A platform description, Table III breakdown, Fig 14 flush
//! microbenchmark). Claims preserved are the *ratios between engines*:
//! who blocks on what, and for how long.
//!
//! Model structure (per rank; 4 ranks share a node's write bandwidth):
//!
//! - Training alternates `fwd+bwd` (immutable window) and `update`.
//! - A checkpoint request contributes *blocking* launch work (what Table
//!   III calls metadata/serialize plus scheduling), then background D2H
//!   staging and flushing that progress concurrently with training.
//! - The consistency gate before the next update waits for outstanding
//!   D2H copies (lazy engines) — and D2H cannot begin until the pinned
//!   cache has room, so a slow flush backlog stalls training exactly as
//!   §V-A2 describes.

pub mod approaches;

pub use approaches::{engine_model, EngineModel};

use crate::baselines::EngineKind;
use crate::cluster::Testbed;
use crate::config::{LlmConfig, Parallelism};
use crate::state::partition::{census, RankCensus};
use crate::state::FileKind;
use crate::train::PhaseModel;

/// One simulated experiment.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: LlmConfig,
    pub par: Parallelism,
    pub testbed: Testbed,
    pub iterations: u64,
    /// Checkpoint every `interval` iterations (0 = never).
    pub interval: u64,
    /// Pinned host cache per rank, bytes (paper: 80 GB/node = 20 GB/rank).
    pub host_cache_bytes: u64,
    /// Optional deeper storage tier (the real plane's `TierPipeline`
    /// drain): when set, a flushed checkpoint must ALSO drain from the
    /// landing tier to the terminal tier at this per-rank bandwidth
    /// (bytes/s) before it counts as globally persistent. Purely a
    /// background tail — training blocking is unaffected, which is
    /// exactly the tiered-persistence claim.
    pub tier_drain_bps: Option<f64>,
    /// D2H staging lanes for the capture model. `None` (default) keeps
    /// the paper-calibrated aggregate `EngineModel::d2h_bps` — every
    /// published figure is unchanged. `Some(n)` models n concurrent
    /// copy streams explicitly: the effective capture rate becomes
    /// `min(n × d2h_stream_bps, d2h_bps)` (the multi-lane staging
    /// ablation behind `figures gather`).
    pub stager_lanes: Option<usize>,
    /// io_uring submission batching for the I/O models. `None`
    /// (default) keeps the calibrated per-operation costs — every
    /// published figure is unchanged. `Some(d)` models a ring of depth
    /// `d`: batching up to `d` SQEs per `io_uring_enter` amortizes the
    /// per-operation submission overhead `d`-fold (the real plane's
    /// `EngineConfig::uring_queue_depth`, behind `figures uring`).
    pub uring_queue_depth: Option<usize>,
}

impl SimConfig {
    pub fn paper(model: &str, iterations: u64, interval: u64) -> Self {
        let model = LlmConfig::by_name(model).expect("known model");
        let par = Parallelism::paper_default(&model);
        SimConfig {
            model,
            par,
            testbed: Testbed::polaris(),
            iterations,
            interval,
            host_cache_bytes: 20 << 30,
            tier_drain_bps: None,
            stager_lanes: None,
            uring_queue_depth: None,
        }
    }

    pub fn with_dp(mut self, dp: usize) -> Self {
        self.par.dp = dp;
        self
    }

    /// Add a terminal-tier drain at `bps` bytes/s per rank.
    pub fn with_tier_drain(mut self, bps: f64) -> Self {
        self.tier_drain_bps = Some(bps);
        self
    }

    /// Model capture as `lanes` explicit concurrent D2H copy streams.
    pub fn with_stager_lanes(mut self, lanes: usize) -> Self {
        self.stager_lanes = Some(lanes.max(1));
        self
    }

    /// Model io_uring submission batching at this ring depth.
    pub fn with_uring_depth(mut self, depth: usize) -> Self {
        self.uring_queue_depth = Some(depth.max(1));
        self
    }
}

/// Per-operation submission-overhead divisor under the experiment's
/// ring depth: batching up to `d` SQEs per submission syscall amortizes
/// the per-op issue cost about `d`-fold. `None` (no ring) divides by
/// exactly 1.0, leaving the calibrated costs bit-identical.
pub fn uring_amortization(cfg: &SimConfig) -> f64 {
    cfg.uring_queue_depth.map_or(1.0, |d| d.max(1) as f64)
}

/// Effective D2H capture bandwidth of `em` under the experiment's lane
/// count: the calibrated aggregate by default, `min(n × per-stream,
/// aggregate)` when lanes are modeled explicitly.
pub fn effective_d2h_bps(em: &EngineModel, cfg: &SimConfig) -> f64 {
    match cfg.stager_lanes {
        Some(lanes) => {
            (lanes.max(1) as f64 * em.d2h_stream_bps).min(em.d2h_bps)
        }
        None => em.d2h_bps,
    }
}

/// Calibrated restore estimate for the slowest rank: how long a
/// restart spends reading the checkpoint back and uploading it to the
/// device, under an explicit H2D lane count and with/without read
/// coalescing — the read-side mirror of [`capture_time_s`], behind
/// `figures restore` and `bench-restore`.
#[derive(Debug, Clone, Copy)]
pub struct RestoreEstimate {
    /// Storage → host read time (bulk + per-read overheads).
    pub read_s: f64,
    /// Host → device upload time under the lane count.
    pub h2d_s: f64,
    /// End-to-end restore (pipelined: uploads overlap reads after the
    /// first gather run lands).
    pub total_s: f64,
    /// Time until the first tensor is fully materialized on device.
    pub ttft_s: f64,
}

/// Model one rank's restore. The serial pattern streams the checkpoint
/// in 4 MiB chunk reads (`ChunkSource`'s granularity, at least one per
/// layout extent), each paying the per-read overhead
/// (`EngineModel::read_extent_op_s`); coalescing collapses them into
/// ~16 MiB gather runs (at least one per file). Uploads drain through
/// `lanes` H2D streams (`min(lanes × h2d_stream_bps, d2h_bps)`),
/// overlapped with the reads once the first run lands.
pub fn restore_time_s(kind: EngineKind, cfg: &SimConfig, lanes: usize,
                      coalesced: bool) -> RestoreEstimate {
    const COALESCE_BYTES: u64 = 16 << 20;
    /// `restore::source::DEFAULT_CHUNK_BYTES`.
    const SERIAL_CHUNK_BYTES: u64 = 4 << 20;
    let em = engine_model(kind, &cfg.testbed);
    let cs = census(&cfg.model, &cfg.par);
    let rc = cs
        .ranks
        .iter()
        .max_by_key(|r| r.total_bytes())
        .expect("ranks");
    let load = rank_load(rc);
    let payload =
        load.dev_bytes + load.host_tensor_bytes + load.obj_bytes;
    // one extent per tensor plus the object log per file — each is a
    // separate positioned read (possibly several chunks) serially
    let n_extents: u64 = rc
        .files
        .iter()
        .map(|f| f.n_tensors as u64 + 1)
        .sum();
    let share =
        cfg.testbed.node_write_bps / cfg.testbed.gpus_per_node as f64;
    let read_bps = share * em.read_eff;
    let reads = if coalesced {
        payload.div_ceil(COALESCE_BYTES).max(load.n_files)
    } else {
        payload.div_ceil(SERIAL_CHUNK_BYTES).max(n_extents)
    };
    // ring batching amortizes the per-read submission cost (`qd` reads
    // per `io_uring_enter`); qd = 1.0 without a ring
    let qd = uring_amortization(cfg);
    let read_s = payload as f64 / read_bps
        + reads as f64 * em.read_extent_op_s / qd;
    let lane_bps = (lanes.max(1) as f64 * em.h2d_stream_bps)
        .min(em.d2h_bps);
    let h2d_s = payload as f64 / lane_bps;
    // pipeline fill: uploads start once the first run/chunk landed
    let first_bytes = if coalesced {
        COALESCE_BYTES.min(payload)
    } else {
        SERIAL_CHUNK_BYTES.min(payload)
    };
    let fill_s =
        first_bytes as f64 / read_bps + em.read_extent_op_s / qd;
    let total_s = fill_s + read_s.max(h2d_s);
    let ttft_s = fill_s + first_bytes as f64 / lane_bps;
    RestoreEstimate { read_s, h2d_s, total_s, ttft_s }
}

/// Calibrated capture (device→host staging) seconds for the slowest
/// rank of `cfg` under `lanes` staging lanes — the quantity the
/// `figures gather` ablation sweeps (lanes 1/2/4).
pub fn capture_time_s(kind: EngineKind, cfg: &SimConfig, lanes: usize)
    -> f64 {
    let em = engine_model(kind, &cfg.testbed);
    let cs = census(&cfg.model, &cfg.par);
    let rc = cs
        .ranks
        .iter()
        .max_by_key(|r| r.total_bytes())
        .expect("ranks");
    let load = rank_load(rc);
    let cfg = cfg.clone().with_stager_lanes(lanes);
    load.dev_bytes as f64 / effective_d2h_bps(&em, &cfg)
}

/// Calibrated serving estimate: TTFT/completion latency percentiles of
/// `readers` concurrent restore sessions sharing one rank's tier
/// pipeline through the serving plane's gather-run cache — the model
/// behind `figures serve` (the measured counterpart is `bench-serve`).
#[derive(Debug, Clone, Copy)]
pub struct ServeEstimate {
    /// Median time-to-first-tensor per session.
    pub ttft_p50_s: f64,
    /// Tail (p99) time-to-first-tensor.
    pub ttft_p99_s: f64,
    /// Tail (p99) end-to-end completion.
    pub completion_p99_s: f64,
    /// Modeled shared-tier utilization in [0, 1).
    pub utilization: f64,
}

/// Model `readers` concurrent sessions restoring one version through a
/// shared run cache with hit fraction `cache_hit_frac`. Cache hits are
/// host-memory scatters that never touch the shared storage tier, so
/// only the miss fraction contributes to tier utilization; medians
/// inflate linearly with utilization while tails pay the M/M/1-style
/// `1/(1-rho)` queueing blow-up. Pure function of its arguments — it
/// changes no published figure.
pub fn serve_time_s(kind: EngineKind, cfg: &SimConfig, readers: usize,
                    cache_hit_frac: f64) -> ServeEstimate {
    let base = restore_time_s(kind, cfg, 2, true);
    let hit = cache_hit_frac.clamp(0.0, 1.0);
    let m = readers.max(1) as f64;
    // saturating utilization map keeps rho in [0, 1) for any fan-out
    let x = 0.25 * m * (1.0 - hit);
    let rho = x / (1.0 + x);
    // single-session times with the cached read fraction elided (hits
    // still pay the scatter/H2D side)
    let read_eff_s = base.read_s * (1.0 - hit);
    let ttft_1 = base.ttft_s * (1.0 - 0.8 * hit);
    let total_1 = (base.total_s - base.read_s.max(base.h2d_s))
        + read_eff_s.max(base.h2d_s);
    let ttft_p50_s = ttft_1 * (1.0 + 0.25 * rho);
    let tail = 1.0 + 3.0 * rho / (1.0 - rho);
    ServeEstimate {
        ttft_p50_s,
        ttft_p99_s: ttft_p50_s * tail,
        completion_p99_s: total_1 * (1.0 + 0.25 * rho) * tail,
        utilization: rho,
    }
}

/// Calibrated expected-restore-latency estimate under FLAKY-tier
/// parameters — the analytic companion of `figures flaky` (the
/// measured counterpart is the harness's fault matrix).
#[derive(Debug, Clone, Copy)]
pub struct FlakyEstimate {
    /// Expected end-to-end restore under faults/stalls/retries.
    pub mean_s: f64,
    /// Tail (p99) time-to-first-tensor.
    pub ttft_p99_s: f64,
    /// Expected in-place transient retries per gather read.
    pub retries_per_read: f64,
}

/// Model one rank's restore when the fastest tier misbehaves.
/// Transient faults hit each gather read independently with
/// probability `fault_rate` and retry IN PLACE (geometric attempts,
/// mean `1/(1-p)`, each retry paying a ~1 ms backoff plus the re-read);
/// a slow fastest tier adds `stall_s` to every read it serves, which a
/// hedge budget `hedge_s > 0` caps near `hedge_s` + one deeper-tier
/// read; with `quarantine` on, a persistently faulty tier trips its
/// breaker after [`crate::storage::health::QUARANTINE_AFTER`]
/// consecutive errors and later reads bypass it entirely. Pure
/// function of its arguments — it changes no published figure.
pub fn flaky_restore_time_s(kind: EngineKind, cfg: &SimConfig,
                            fault_rate: f64, stall_s: f64,
                            hedge_s: f64, quarantine: bool)
    -> FlakyEstimate {
    /// Mean retry backoff of `storage::health::RetryPolicy`'s default
    /// capped-exponential schedule (0.5 ms base, 20 ms cap, ~4 tries).
    const MEAN_BACKOFF_S: f64 = 1e-3;
    let base = restore_time_s(kind, cfg, 2, true);
    let p = fault_rate.clamp(0.0, 0.5);
    let stall = stall_s.max(0.0);
    let hedge = hedge_s.max(0.0);
    // the coalesced gather-read count of `restore_time_s`
    let cs = census(&cfg.model, &cfg.par);
    let rc = cs
        .ranks
        .iter()
        .max_by_key(|r| r.total_bytes())
        .expect("ranks");
    let load = rank_load(rc);
    let payload =
        load.dev_bytes + load.host_tensor_bytes + load.obj_bytes;
    let reads =
        payload.div_ceil(16 << 20).max(load.n_files).max(1) as f64;
    let per_read_s = base.read_s / reads;
    // geometric retry tail per read; with the breaker on, only the
    // reads BEFORE the quarantine trip pay it (the trip needs
    // ~QUARANTINE_AFTER consecutive faults, expected after about
    // QUARANTINE_AFTER / p reads), later reads resolve directly on
    // the healthy deeper tier
    let retries_per_read = p / (1.0 - p);
    let faulty_reads = if quarantine && p > 0.0 {
        (crate::storage::health::QUARANTINE_AFTER as f64 / p)
            .min(reads)
    } else {
        reads
    };
    let retry_s = faulty_reads
        * retries_per_read
        * (MEAN_BACKOFF_S + per_read_s);
    // slow-tier stall per read: hedging caps it at the hedge budget
    // plus one deeper-tier read (modeled at 2x the per-read cost —
    // the next tier is slower, that is why it was not nearest)
    let stall_per_read = if hedge > 0.0 && stall > hedge {
        hedge + 2.0 * per_read_s
    } else {
        stall
    };
    let stall_total_s = reads * stall_per_read;
    let mean_s = base.total_s + retry_s + stall_total_s;
    // the first tensor waits on the first read: its stall (hedged or
    // not) plus a fault-tail inflation
    let ttft_p99_s =
        (base.ttft_s + stall_per_read) * (1.0 + 3.0 * p)
            + retries_per_read * (MEAN_BACKOFF_S + per_read_s);
    FlakyEstimate { mean_s, ttft_p99_s, retries_per_read }
}

/// Calibrated incremental-upload estimate for the content-addressed
/// remote tier (`storage::content`): what the v2 upload of a two-version
/// incremental run costs over a WAN link, versus re-uploading the full
/// checkpoint — the model behind `figures incremental`.
#[derive(Debug, Clone, Copy)]
pub struct IncrementalEstimate {
    /// Content chunks in the full checkpoint.
    pub chunks_total: u64,
    /// Chunks the dirty fraction forces back over the wire.
    pub chunks_uploaded: u64,
    /// Bytes actually uploaded (dedup'd chunks cost nothing).
    pub upload_bytes: u64,
    /// Incremental upload seconds (latency + throttled dirty bytes).
    pub upload_s: f64,
    /// Full re-upload seconds for comparison.
    pub full_s: f64,
}

impl IncrementalEstimate {
    /// Full-upload over incremental-upload time.
    pub fn speedup(&self) -> f64 {
        if self.upload_s > 0.0 {
            self.full_s / self.upload_s
        } else {
            f64::INFINITY
        }
    }
}

/// Model the v2 upload of an incremental checkpoint: `dirty_frac` of the
/// `chunk_bytes`-aligned content chunks changed since v1 and must be
/// re-uploaded through a `remote_bps` token bucket after one
/// `latency_s` request round-trip (the WAN shim charges latency once
/// per file commit and bandwidth on uploaded bytes only — dedup'd
/// chunks are metadata-only).
pub fn incremental_upload_time_s(total_bytes: u64, dirty_frac: f64,
                                 chunk_bytes: usize, remote_bps: f64,
                                 latency_s: f64) -> IncrementalEstimate {
    let chunk_bytes = chunk_bytes.max(64) as u64;
    let dirty = dirty_frac.clamp(0.0, 1.0);
    let chunks_total = total_bytes.div_ceil(chunk_bytes);
    let chunks_uploaded =
        ((chunks_total as f64 * dirty).ceil() as u64).min(chunks_total);
    let upload_bytes = (chunks_uploaded * chunk_bytes).min(total_bytes);
    let upload_s = latency_s + upload_bytes as f64 / remote_bps;
    let full_s = latency_s + total_bytes as f64 / remote_bps;
    IncrementalEstimate {
        chunks_total,
        chunks_uploaded,
        upload_bytes,
        upload_s,
        full_s,
    }
}

/// Per-iteration simulated outcome (slowest rank).
#[derive(Debug, Clone, Default)]
pub struct IterSample {
    /// Pure training compute+comm time.
    pub train_s: f64,
    /// Time training was blocked by checkpointing this iteration
    /// (launch + gate waits + cache-full waits + synchronous work).
    pub blocked_s: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub kind: EngineKind,
    pub iters: Vec<IterSample>,
    /// End-to-end wall time including the final drain of background
    /// flushes.
    pub total_s: f64,
    /// Global checkpoint size (all ranks), bytes.
    pub global_ckpt_bytes: u64,
    /// Per-rank checkpoint size (slowest rank), bytes.
    pub rank_ckpt_bytes: u64,
    /// Number of checkpoints taken.
    pub checkpoints: u64,
    /// Mean blocked seconds per checkpoint.
    pub mean_blocked_s: f64,
}

impl SimResult {
    /// The paper's "effective checkpoint throughput": global size over
    /// the time training was blocked per checkpoint.
    pub fn effective_bps(&self) -> f64 {
        if self.checkpoints == 0 || self.mean_blocked_s <= 0.0 {
            return f64::INFINITY;
        }
        self.global_ckpt_bytes as f64 / self.mean_blocked_s
    }

    pub fn mean_iteration_s(&self) -> f64 {
        self.total_s / self.iters.len().max(1) as f64
    }
}

/// Quantities of one rank's checkpoint composition used by the engine
/// models.
#[derive(Debug, Clone, Copy)]
pub struct RankLoad {
    /// Device-resident tensor bytes (params + optimizer partition).
    pub dev_bytes: u64,
    /// Host-resident tensor bytes (tiny).
    pub host_tensor_bytes: u64,
    /// Serialized object-graph bytes.
    pub obj_bytes: u64,
    /// Object-graph node estimate (serializer traversal cost driver).
    pub obj_nodes: u64,
    /// Number of checkpoint files.
    pub n_files: u64,
}

pub fn rank_load(rc: &RankCensus) -> RankLoad {
    let mut l = RankLoad {
        dev_bytes: 0,
        host_tensor_bytes: 0,
        obj_bytes: 0,
        obj_nodes: 0,
        n_files: rc.files.len() as u64,
    };
    for f in &rc.files {
        if f.on_device {
            l.dev_bytes += f.tensor_bytes;
        } else {
            l.host_tensor_bytes += f.tensor_bytes;
        }
        l.obj_bytes += f.object_bytes;
        l.obj_nodes += f.object_bytes / 80; // ~80 B per graph node
    }
    l
}

/// Simulate one engine on one configuration.
pub fn simulate(kind: EngineKind, cfg: &SimConfig) -> SimResult {
    let em = engine_model(kind, &cfg.testbed);
    simulate_core(kind, em, cfg)
}

/// Simulate an explicit behaviour model (ablation studies).
pub fn simulate_with_model(em: EngineModel, cfg: &SimConfig) -> SimResult {
    simulate_core(EngineKind::DataStatesLlm, em, cfg)
}

fn simulate_core(kind: EngineKind, em: EngineModel, cfg: &SimConfig)
    -> SimResult {
    let phases = PhaseModel::polaris().phases(&cfg.model, &cfg.par);
    let cs = census(&cfg.model, &cfg.par);
    // slowest rank: largest per-rank payload (stage-0 rank of replica 0)
    let rc = cs
        .ranks
        .iter()
        .max_by_key(|r| r.total_bytes())
        .expect("ranks");
    let load = rank_load(rc);
    let global_bytes: u64 =
        cs.ranks.iter().map(|r| r.total_bytes()).sum();
    let rank_bytes = rc.total_bytes();

    // Per-rank write bandwidth: node write bw is shared by the node's
    // ranks (4/node), scaled by the engine's achieved efficiency, with
    // an absolute per-rank cap for single-threaded writers.
    let ranks_per_node = cfg.testbed.gpus_per_node as f64;
    let share = cfg.testbed.node_write_bps / ranks_per_node;
    let write_bps = (share * em.write_eff).min(em.write_cap_bps);
    // capture rate: calibrated aggregate, or explicit lane modeling
    let d2h_bps = effective_d2h_bps(&em, cfg);

    let ser_time = |bytes: u64, nodes: u64| {
        bytes as f64 / cfg.testbed.serialize_bps
            + nodes as f64 * cfg.testbed.serialize_per_node_s
    };
    // Lustre MDT contention: per-op cost grows with the number of
    // concurrent clients per MDT (40 MDTs on Polaris; §II cites metadata
    // server bottlenecks from the file-count explosion).
    let md_contention = 1.0 + cfg.par.world() as f64 / 40.0;
    // write-side ring batching: per-file op ISSUE cost amortizes with
    // queue depth (the MDT contention factor itself does not — the
    // server-side bottleneck stays)
    let qd = uring_amortization(cfg);
    let md_ops = |files: u64| {
        files as f64 * cfg.testbed.pfs_metadata_op_s * md_contention
            / qd
    };

    // background flush state (virtual time when the queue drains, bytes
    // resident in the pinned cache)
    let mut t = 0.0f64;
    let mut flush_done_at = 0.0f64;
    // tiered persistence: the terminal-tier drain trails the flush
    let mut drain_done_at = 0.0f64;
    let mut cache_frees_at: Vec<(f64, u64)> = Vec::new(); // (time, bytes)
    let mut cache_used = 0u64;
    // lazy engines: D2H completion time of the pending snapshot
    let mut pending_d2h_done = 0.0f64;

    let mut iters = Vec::with_capacity(cfg.iterations as usize);
    let mut checkpoints = 0u64;
    let mut total_blocked = 0.0f64;

    for it in 0..cfg.iterations {
        let mut blocked = 0.0f64;

        // forward + backward (immutable window; D2H staging overlaps)
        t += phases.compute_s();

        // consistency gate before the update
        if em.lazy_capture && pending_d2h_done > t {
            let wait = pending_d2h_done - t;
            t += wait;
            blocked += wait;
        }

        // update phase
        t += phases.update_s;

        // checkpoint request?
        if cfg.interval > 0 && (it + 1) % cfg.interval == 0 {
            checkpoints += 1;
            // reclaim cache space freed by completed flushes
            cache_frees_at.retain(|(done, bytes)| {
                if *done <= t {
                    cache_used -= *bytes;
                    false
                } else {
                    true
                }
            });

            let payload = load.dev_bytes + load.host_tensor_bytes
                + load.obj_bytes;

            if em.fully_blocking {
                // DeepSpeed default: everything on the critical path
                let d2h = load.dev_bytes as f64 / d2h_bps;
                let deep_copy = if em.serialize_tensors {
                    payload as f64 / cfg.testbed.host_memcpy_bps
                        + ser_time(payload, load.obj_nodes)
                } else {
                    ser_time(load.obj_bytes, load.obj_nodes)
                };
                let write = payload as f64 / write_bps
                    + md_ops(load.n_files);
                let cost = d2h + deep_copy + write;
                t += cost;
                blocked += cost;
            } else if !em.lazy_capture {
                // TorchSnapshot: one outstanding snapshot — wait for the
                // previous flush to finish before capturing again
                if flush_done_at > t {
                    let wait = flush_done_at - t;
                    t += wait;
                    blocked += wait;
                }
                // blocking snapshot: synchronous D2H + small serialize
                let snap = load.dev_bytes as f64 / d2h_bps
                    + ser_time(load.obj_bytes, load.obj_nodes)
                    + payload as f64 * em.plan_per_byte_s;
                t += snap;
                blocked += snap;
                // background flush (chunk files inflate metadata ops)
                let files = if em.chunk_files {
                    load.n_files
                        + payload.div_ceil(em.chunk_bytes)
                } else {
                    load.n_files
                };
                let dur = payload as f64 / write_bps + md_ops(files);
                flush_done_at = t.max(flush_done_at) + dur;
            } else {
                // lazy engines (old + new)
                // blocking launch: per-file plan/launch overhead, plus
                // metadata-first serialization for the old engine
                let mut launch = load.n_files as f64 * em.launch_per_file_s
                    + payload as f64 * em.plan_per_byte_s;
                if em.metadata_first {
                    launch += ser_time(load.obj_bytes, load.obj_nodes);
                }
                t += launch;
                blocked += launch;

                // cache backpressure: D2H cannot start until there is
                // room for this snapshot
                let mut d2h_start = t;
                if cache_used + load.dev_bytes > cfg.host_cache_bytes {
                    // wait for enough pending frees (FIFO)
                    let mut needed =
                        (cache_used + load.dev_bytes)
                            .saturating_sub(cfg.host_cache_bytes);
                    let mut frees = cache_frees_at.clone();
                    frees.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    for (done, bytes) in frees {
                        if needed == 0 {
                            break;
                        }
                        d2h_start = d2h_start.max(done);
                        needed = needed.saturating_sub(bytes);
                        // consume the free
                        if let Some(pos) = cache_frees_at
                            .iter()
                            .position(|(d, b)| *d == done && *b == bytes)
                        {
                            cache_used -= bytes;
                            cache_frees_at.remove(pos);
                        }
                    }
                }
                cache_used += load.dev_bytes;

                // lazy D2H over the next immutable window (pinned)
                pending_d2h_done =
                    d2h_start + load.dev_bytes as f64 / d2h_bps;

                // background flush
                let flush_work = payload as f64 / write_bps
                    + md_ops(load.n_files);
                let start = if em.streaming {
                    // chunks flush while staging: start immediately,
                    // bounded below by staging rate
                    d2h_start
                } else {
                    // snapshot-then-flush per file: wait for staging
                    pending_d2h_done
                };
                flush_done_at = flush_done_at.max(start) + flush_work;
                cache_frees_at.push((flush_done_at, load.dev_bytes));
            }

            // tier pipeline: the checkpoint just flushed still has to
            // drain to the terminal tier (background only — never
            // blocks). A fully-blocking engine finished its write at
            // the current `t` (it never populates flush_done_at).
            if let Some(bps) = cfg.tier_drain_bps {
                let flushed_at = if em.fully_blocking {
                    t
                } else {
                    flush_done_at
                };
                drain_done_at = drain_done_at.max(flushed_at)
                    + payload as f64 / bps;
            }
        }

        total_blocked += blocked;
        iters.push(IterSample { train_s: phases.total_s(), blocked_s: blocked });
    }
    // drain the background tail
    if flush_done_at > t {
        t = flush_done_at;
    }
    if drain_done_at > t {
        t = drain_done_at;
    }
    if pending_d2h_done > t {
        t = pending_d2h_done;
    }

    SimResult {
        kind,
        iters,
        total_s: t,
        global_ckpt_bytes: global_bytes,
        rank_ckpt_bytes: rank_bytes,
        checkpoints,
        mean_blocked_s: if checkpoints > 0 {
            total_blocked / checkpoints as f64
        } else {
            0.0
        },
    }
}

/// Where a restart reads a lost rank's shards from: the nearest tier
/// (or peer replica copy) that survives the failure domain, described
/// by its access characteristics. Used by the MTTI-aware lost-work
/// model ([`expected_lost_work_s`]) to weigh checkpoint interval
/// against restore depth.
#[derive(Debug, Clone, Copy)]
pub struct TierPlacement {
    /// Per-request access latency of the surviving copy's tier
    /// (0 for node-local tiers; RPC/object-store latency for remote;
    /// network hop for a peer replica).
    pub latency_s: f64,
    /// Sustained read bandwidth of that tier, bytes/s.
    pub read_bps: f64,
    /// Checkpoint bytes the restart must read back (per rank).
    pub bytes: u64,
}

impl TierPlacement {
    /// Seconds to re-read the checkpoint from this placement.
    pub fn restore_s(&self) -> f64 {
        self.latency_s + self.bytes as f64 / self.read_bps.max(1.0)
    }
}

/// Expected training seconds lost per HOUR of wall-clock training,
/// under mean-time-to-interrupt `mtti_s`, checkpointing every
/// `interval_s`, restoring from `placement` after each failure.
///
/// Per failure the run loses the progress since the last checkpoint
/// (uniform failure arrival ⇒ `interval_s / 2` in expectation) plus
/// the restore time of the surviving copy (`placement.restore_s()`);
/// failures arrive at rate `1 / mtti_s`, so the hourly expectation is
/// `3600 / mtti_s × (interval_s / 2 + restore_s)`. Monotone the way a
/// placement decision needs: shorter interval ⇒ less lost work,
/// faster/nearer surviving tier ⇒ less, larger MTTI ⇒ less — the
/// quantitative backbone of the replication trade-off (`--replicas K`
/// keeps the surviving copy on a PEER's fast tier instead of the deep
/// remote tier, shrinking `restore_s` at the cost of replica pushes).
pub fn expected_lost_work_s(mtti_s: f64, interval_s: f64,
                            placement: &TierPlacement) -> f64 {
    assert!(mtti_s > 0.0 && mtti_s.is_finite(),
            "mtti_s must be positive, got {mtti_s}");
    assert!(interval_s >= 0.0 && interval_s.is_finite(),
            "interval_s must be >= 0, got {interval_s}");
    let per_failure = interval_s / 2.0 + placement.restore_s();
    3600.0 / mtti_s * per_failure
}

/// Aggregate Table-I-style census numbers used by figure drivers.
pub fn global_files(cfg: &SimConfig) -> u64 {
    census(&cfg.model, &cfg.par)
        .ranks
        .iter()
        .map(|r| r.files.len() as u64)
        .sum()
}

/// Per-kind census: (metadata, params, optimizer) file counts.
pub fn file_census(cfg: &SimConfig) -> (u64, u64, u64) {
    let cs = census(&cfg.model, &cfg.par);
    let count = |k: FileKind| {
        cs.ranks
            .iter()
            .flat_map(|r| r.files.iter())
            .filter(|f| f.kind == k)
            .count() as u64
    };
    (
        count(FileKind::Metadata),
        count(FileKind::ParamLayer),
        count(FileKind::Optimizer),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(kind: EngineKind, model: &str) -> SimResult {
        simulate(kind, &SimConfig::paper(model, 15, 1))
    }

    #[test]
    fn flaky_restore_model_is_monotone_and_hedging_cuts_the_tail() {
        let cfg = SimConfig::paper("3B", 15, 1);
        let k = EngineKind::DataStatesLlm;
        let at = |p, stall, hedge, q| {
            flaky_restore_time_s(k, &cfg, p, stall, hedge, q)
        };
        // no faults, no stall => the plain restore estimate
        let base = restore_time_s(k, &cfg, 2, true);
        let clean = at(0.0, 0.0, 0.0, false);
        assert!((clean.mean_s - base.total_s).abs() < 1e-9);
        assert_eq!(clean.retries_per_read, 0.0);
        // mean latency grows with the fault rate
        assert!(at(0.02, 0.0, 0.0, false).mean_s
                < at(0.05, 0.0, 0.0, false).mean_s);
        assert!(at(0.05, 0.0, 0.0, false).mean_s
                < at(0.10, 0.0, 0.0, false).mean_s);
        // quarantine caps the fault tax on a persistently flaky tier
        assert!(at(0.10, 0.0, 0.0, true).mean_s
                <= at(0.10, 0.0, 0.0, false).mean_s);
        // hedging strictly cuts the p99 TTFT when the stall exceeds
        // the hedge budget...
        let stalled = at(0.0, 0.050, 0.0, false);
        let hedged = at(0.0, 0.050, 0.002, false);
        assert!(hedged.ttft_p99_s < stalled.ttft_p99_s,
                "hedged {} vs stalled {}",
                hedged.ttft_p99_s, stalled.ttft_p99_s);
        // ...and is a no-op when the primary beats the budget
        let fast = at(0.0, 0.0, 0.002, false);
        assert!((fast.ttft_p99_s - clean.ttft_p99_s).abs() < 1e-9);
    }

    #[test]
    fn expected_lost_work_is_monotone() {
        let fast = TierPlacement {
            latency_s: 0.0,
            read_bps: 10e9,
            bytes: 20 << 30,
        };
        let slow = TierPlacement {
            latency_s: 0.020,
            read_bps: 1e9,
            bytes: 20 << 30,
        };
        let mtti = 6.0 * 3600.0;
        // shorter interval => less lost work
        assert!(expected_lost_work_s(mtti, 60.0, &fast)
                < expected_lost_work_s(mtti, 600.0, &fast));
        // faster surviving tier => less lost work
        assert!(expected_lost_work_s(mtti, 60.0, &fast)
                < expected_lost_work_s(mtti, 60.0, &slow));
        // larger MTTI => less lost work
        assert!(expected_lost_work_s(2.0 * mtti, 60.0, &fast)
                < expected_lost_work_s(mtti, 60.0, &fast));
        // and the closed form itself: 1 failure/hour, 60s interval,
        // 2s restore => 32s lost per hour
        let unit = TierPlacement {
            latency_s: 1.0,
            read_bps: 1e9,
            bytes: 1 << 30,
        };
        let got = expected_lost_work_s(3600.0, 60.0, &unit);
        assert!((got - (30.0 + 1.0 + 1.0737)).abs() < 0.01,
                "got {got}");
    }

    #[test]
    fn datastates_beats_baselines_on_e2e_time() {
        // Fig 9 shape: ds-llm < ds-old < torchsnapshot < deepspeed
        for model in ["3B", "7B", "13B"] {
            let ds = run(EngineKind::DeepSpeedDefault, model).total_s;
            let ts = run(EngineKind::TorchSnapshot, model).total_s;
            let old = run(EngineKind::DataStatesOld, model).total_s;
            let new = run(EngineKind::DataStatesLlm, model).total_s;
            assert!(new < old && old < ts && ts < ds,
                    "{model}: new={new:.1} old={old:.1} ts={ts:.1} ds={ds:.1}");
        }
    }

    #[test]
    fn effective_throughput_ratios_match_paper_envelope() {
        // Fig 7: ds-llm at least 2x over DeepSpeed/TorchSnapshot, and
        // 1.2x-7x over ds-old.
        for model in ["3B", "7B", "13B", "33B", "70B"] {
            let ds = run(EngineKind::DeepSpeedDefault, model)
                .effective_bps();
            let ts = run(EngineKind::TorchSnapshot, model).effective_bps();
            let old = run(EngineKind::DataStatesOld, model)
                .effective_bps();
            let new = run(EngineKind::DataStatesLlm, model)
                .effective_bps();
            assert!(new >= 2.0 * ds.max(ts),
                    "{model}: new={new:.2e} ds={ds:.2e} ts={ts:.2e}");
            assert!(new >= 1.15 * old, "{model}: new={new:.2e} old={old:.2e}");
        }
    }

    #[test]
    fn throughput_grows_with_model_size() {
        // Fig 7: larger models -> more nodes + longer iterations -> higher
        // effective throughput for every engine.
        for kind in EngineKind::all() {
            let small = run(kind, "3B").effective_bps();
            let large = run(kind, "70B").effective_bps();
            assert!(large > small,
                    "{}: 3B={small:.2e} 70B={large:.2e}", kind.label());
        }
    }

    #[test]
    fn serve_model_is_monotone_in_readers_and_hit_rate() {
        let cfg = SimConfig::paper("7B", 15, 1);
        let est = |readers, hit| {
            serve_time_s(EngineKind::DataStatesLlm, &cfg, readers, hit)
        };
        // more concurrent readers -> worse tails at a fixed hit rate
        let mut prev = est(1, 0.5);
        for readers in [4, 16, 64, 256] {
            let e = est(readers, 0.5);
            assert!(e.ttft_p99_s > prev.ttft_p99_s, "{readers}");
            assert!(e.completion_p99_s > prev.completion_p99_s);
            prev = e;
        }
        // better hit rate -> strictly better tails at a fixed fan-out
        let mut prev = est(64, 0.0);
        for hit in [0.25, 0.5, 0.9, 0.99] {
            let e = est(64, hit);
            assert!(e.ttft_p99_s < prev.ttft_p99_s, "{hit}");
            assert!(e.completion_p99_s < prev.completion_p99_s);
            assert!(e.utilization < prev.utilization);
            prev = e;
        }
        // internal ordering + sanity at every cell
        for readers in [1, 64] {
            for hit in [0.0, 0.5, 0.98] {
                let e = est(readers, hit);
                assert!(e.ttft_p50_s > 0.0);
                assert!(e.ttft_p99_s >= e.ttft_p50_s);
                assert!(e.completion_p99_s >= e.ttft_p50_s);
                assert!((0.0..1.0).contains(&e.utilization));
            }
        }
    }

    #[test]
    fn incremental_upload_model_is_monotone_and_bounded() {
        let total = 1u64 << 30;
        let est = |dirty: f64, bps: f64| {
            incremental_upload_time_s(total, dirty, 256 << 10, bps, 0.05)
        };
        // more dirt -> more chunks, more bytes, more time
        let mut prev = est(0.0, 100e6);
        for dirty in [0.02, 0.1, 0.5, 1.0] {
            let e = est(dirty, 100e6);
            assert!(e.chunks_uploaded >= prev.chunks_uploaded);
            assert!(e.upload_bytes >= prev.upload_bytes);
            assert!(e.upload_s >= prev.upload_s, "{dirty}");
            assert!(e.upload_s <= e.full_s);
            assert!(e.chunks_uploaded <= e.chunks_total);
            prev = e;
        }
        // full dirt degenerates to the full upload
        let full = est(1.0, 100e6);
        assert_eq!(full.chunks_uploaded, full.chunks_total);
        assert!((full.upload_s - full.full_s).abs() < 1e-2);
        assert!((full.speedup() - 1.0).abs() < 0.05);
        // 10% dirty over WAN: order-of-magnitude faster than full
        let incr = est(0.1, 100e6);
        assert!(incr.speedup() > 4.0, "speedup {}", incr.speedup());
        // faster link -> less time
        assert!(est(0.1, 1e9).upload_s < est(0.1, 100e6).upload_s);
    }

    #[test]
    fn larger_interval_reduces_e2e_time() {
        // Fig 13 shape.
        let t1 = simulate(EngineKind::DataStatesLlm,
                          &SimConfig::paper("7B", 50, 1)).total_s;
        let t10 = simulate(EngineKind::DataStatesLlm,
                           &SimConfig::paper("7B", 50, 10)).total_s;
        assert!(t10 < t1);
    }

    #[test]
    fn dp_scaling_shrinks_per_rank_payload() {
        // Fig 12: ZeRO-1 divides the optimizer shard across replicas.
        let r1 = simulate(EngineKind::DataStatesLlm,
                          &SimConfig::paper("13B", 5, 1).with_dp(1));
        let r16 = simulate(EngineKind::DataStatesLlm,
                           &SimConfig::paper("13B", 5, 1).with_dp(16));
        assert!(r16.rank_ckpt_bytes < r1.rank_ckpt_bytes / 8);
    }

    #[test]
    fn no_checkpointing_means_no_blocking() {
        let r = simulate(EngineKind::DataStatesLlm,
                         &SimConfig::paper("7B", 10, 0));
        assert_eq!(r.checkpoints, 0);
        assert!(r.iters.iter().all(|i| i.blocked_s == 0.0));
    }

    #[test]
    fn second_stager_lane_strictly_cuts_capture_time() {
        // the figures-gather ablation's calibrated claim: one lane
        // cannot saturate pinned PCIe, two can; beyond saturation more
        // lanes stop helping
        let cfg = SimConfig::paper("7B", 15, 1);
        let t1 = capture_time_s(EngineKind::DataStatesLlm, &cfg, 1);
        let t2 = capture_time_s(EngineKind::DataStatesLlm, &cfg, 2);
        let t4 = capture_time_s(EngineKind::DataStatesLlm, &cfg, 4);
        assert!(t2 < t1, "lanes=2 {t2:.3}s !< lanes=1 {t1:.3}s");
        assert!(t4 <= t2);
        // and the lane model never beats the calibrated aggregate
        let em = engine_model(EngineKind::DataStatesLlm, &cfg.testbed);
        let many = cfg.clone().with_stager_lanes(64);
        assert!((effective_d2h_bps(&em, &many) - em.d2h_bps).abs()
                < 1.0);
        // default (no lanes set) keeps published figures bit-identical
        assert!((effective_d2h_bps(&em, &cfg) - em.d2h_bps).abs() < 1.0);
    }

    #[test]
    fn coalesced_two_lane_restore_strictly_beats_serial() {
        // the PR-5 acceptance claim in the calibrated plane:
        // restore(lanes=2, coalesced) < restore(lanes=1, uncoalesced)
        let cfg = SimConfig::paper("7B", 15, 1);
        let kind = EngineKind::DataStatesLlm;
        let fast = restore_time_s(kind, &cfg, 2, true);
        let slow = restore_time_s(kind, &cfg, 1, false);
        assert!(fast.total_s < slow.total_s,
                "coalesced 2-lane {:.3}s !< serial {:.3}s",
                fast.total_s, slow.total_s);
        // each knob also helps on its own
        assert!(restore_time_s(kind, &cfg, 1, true).read_s
                < slow.read_s);
        assert!(restore_time_s(kind, &cfg, 2, false).h2d_s
                < slow.h2d_s);
        // more lanes never hurt; beyond PCIe saturation they stop
        // helping
        let l4 = restore_time_s(kind, &cfg, 4, true);
        assert!(l4.total_s <= fast.total_s + 1e-9);
        // first tensor lands strictly before the full restore
        for est in [fast, slow, l4] {
            assert!(est.ttft_s < est.total_s,
                    "ttft {:.3} !< total {:.3}", est.ttft_s,
                    est.total_s);
        }
    }

    #[test]
    fn explicit_lanes_thread_through_the_full_simulation() {
        // e2e totals under the lane model stay ordered: one lane can
        // only be slower-or-equal than two (the gate and the cache
        // drain both move with the capture rate)
        let base = SimConfig::paper("7B", 15, 1);
        let l1 = simulate(EngineKind::DataStatesLlm,
                          &base.clone().with_stager_lanes(1));
        let l2 = simulate(EngineKind::DataStatesLlm,
                          &base.clone().with_stager_lanes(2));
        assert!(l1.total_s >= l2.total_s * 0.999,
                "lanes=1 {:.2}s vs lanes=2 {:.2}s",
                l1.total_s, l2.total_s);
    }

    #[test]
    fn deeper_uring_queue_never_slows_the_modeled_io() {
        let base = SimConfig::paper("7B", 15, 1);
        let kind = EngineKind::DataStatesLlm;
        // uncoalesced restores issue one op per chunk, so batching is
        // strictly faster and monotone in depth
        let serial = restore_time_s(kind, &base, 2, false);
        let mut prev = serial.read_s;
        for d in [2usize, 8, 64] {
            let cfg = base.clone().with_uring_depth(d);
            let est = restore_time_s(kind, &cfg, 2, false);
            assert!(est.read_s < prev,
                    "depth {d}: {:.4}s !< {prev:.4}s", est.read_s);
            assert!(est.total_s <= serial.total_s + 1e-12);
            assert!(est.ttft_s <= serial.ttft_s + 1e-12);
            prev = est.read_s;
        }
        // coalesced restores have few ops left to amortize: never
        // slower, gain bounded by the serial gain
        let co = restore_time_s(kind, &base, 2, true);
        let co64 = restore_time_s(
            kind, &base.clone().with_uring_depth(64), 2, true);
        assert!(co64.read_s <= co.read_s + 1e-12);
        assert!(co.read_s - co64.read_s
                    <= serial.read_s - prev + 1e-12,
                "coalescing left more op cost than serial?");
        // the write model amortizes too: e2e never slower with a ring
        let flat = simulate(kind, &base);
        let ring =
            simulate(kind, &base.clone().with_uring_depth(64));
        assert!(ring.total_s <= flat.total_s + 1e-9,
                "ring {:.2}s vs flat {:.2}s", ring.total_s,
                flat.total_s);
        // depth 1 is bit-identical to no ring at all (divisor 1.0)
        let d1 = restore_time_s(
            kind, &base.clone().with_uring_depth(1), 2, false);
        assert_eq!(d1.read_s.to_bits(), serial.read_s.to_bits());
    }

    #[test]
    fn tier_drain_extends_tail_but_never_blocks_training() {
        // The tiered-persistence claim in the sim plane: a slow
        // terminal-tier drain lengthens the background tail, not the
        // per-iteration blocked time.
        let base = SimConfig::paper("7B", 15, 1);
        let fast = simulate(EngineKind::DataStatesLlm, &base);
        let tiered = simulate(
            EngineKind::DataStatesLlm,
            &base.clone().with_tier_drain(0.2e9), // slow PFS drain
        );
        assert!(tiered.total_s > fast.total_s,
                "tiered {:.1} vs flat {:.1}", tiered.total_s, fast.total_s);
        assert!((tiered.mean_blocked_s - fast.mean_blocked_s).abs()
                    < 1e-9,
                "drain must not change blocking: {:.4} vs {:.4}",
                tiered.mean_blocked_s, fast.mean_blocked_s);
    }
}
