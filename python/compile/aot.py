"""AOT compile path: lower the L2/L1 computations to HLO **text**.

Interchange format is HLO text, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (``make artifacts``):

- ``train_step.hlo.txt``  — fwd+bwd+Adam over the e2e ModelConfig
- ``fwd_loss.hlo.txt``    — forward loss only (restore verification)
- ``init_state.hlo.txt``  — deterministic state init from a seed scalar
- ``attn_pallas.hlo.txt`` — the L1 Pallas attention kernel (parity tests)
- ``adam_pallas.hlo.txt`` — the L1 fused-Adam kernel (parity tests)
- ``read_tail.hlo.txt``   — (step, loss) scalar readback slice
- ``manifest.json``       — leaf names/shapes/offsets + calling convention

Python runs once, at build time; the rust binary is self-contained after
artifacts exist.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import adam as adam_kernel
from .kernels import attention as attn_kernel


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """stablehlo -> XlaComputation -> HLO text.

    ``return_tuple=False`` is used for the packed train/init/loss
    computations whose single array result must come back as a plain
    buffer (device-resident state loop in rust); the Pallas parity
    artifacts keep tuple results and are unwrapped with ``to_tuple``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_train_step(cfg: model.ModelConfig, batch: int):
    n = model.packed_len(cfg)
    tok_spec = _spec((batch, cfg.seq_len + 1), jnp.int32)

    def fn(flat, tokens):
        return model.train_step_packed(flat, tokens, cfg)

    return jax.jit(fn).lower(_spec((n,)), tok_spec)


def lower_fwd_loss(cfg: model.ModelConfig, batch: int):
    n = model.packed_len(cfg)
    tok_spec = _spec((batch, cfg.seq_len + 1), jnp.int32)

    def fn(flat, tokens):
        return model.fwd_loss_packed(flat, tokens, cfg)

    return jax.jit(fn).lower(_spec((n,)), tok_spec)


def lower_init_state(cfg: model.ModelConfig):
    def fn(seed):
        return model.init_state_packed(seed, cfg)

    return jax.jit(fn).lower(_spec((), jnp.int32))


def lower_read_tail(cfg: model.ModelConfig):
    """Slice out [step, loss] — the CPU PJRT plugin lacks raw-offset
    D2H copies, so the scalar readback is its own tiny computation."""
    n = model.packed_len(cfg)

    def fn(flat):
        return jax.lax.dynamic_slice(flat, (n - 2,), (2,))

    return jax.jit(fn).lower(_spec((n,)))


def lower_attn_pallas(b=1, h=4, t=64, dh=32):
    s = _spec((b, h, t, dh))

    def fn(q, k, v):
        return (attn_kernel.attention(q, k, v, causal=True,
                                      block_q=32, block_k=32),)

    return jax.jit(fn).lower(s, s, s), dict(b=b, h=h, t=t, dh=dh)


def lower_adam_pallas(n=4096):
    s = _spec((n,))

    def fn(p, m, v, g, step):
        return adam_kernel.adam_update(p, m, v, g, step, block=1024)

    return jax.jit(fn).lower(s, s, s, s, _spec((), jnp.float32)), dict(n=n)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tiny", action="store_true",
                    help="use the TINY config (CI / quick tests)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.TINY if args.tiny else model.ModelConfig()
    nparams = cfg.num_params()
    print(f"model config: {cfg} ({nparams/1e6:.1f}M params)")

    outputs = {}

    def emit(name, lowered, return_tuple=True):
        text = to_hlo_text(lowered, return_tuple=return_tuple)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outputs[name] = len(text)
        print(f"  wrote {path} ({len(text)/1e6:.2f} MB)")

    # packed computations: single array results, no tuple wrapper
    emit("train_step", lower_train_step(cfg, args.batch),
         return_tuple=False)
    emit("fwd_loss", lower_fwd_loss(cfg, args.batch), return_tuple=False)
    emit("init_state", lower_init_state(cfg), return_tuple=False)
    emit("read_tail", lower_read_tail(cfg), return_tuple=False)
    attn_lowered, attn_shape = lower_attn_pallas()
    emit("attn_pallas", attn_lowered)
    adam_lowered, adam_shape = lower_adam_pallas()
    emit("adam_pallas", adam_lowered)

    manifest = {
        "config": dataclasses.asdict(cfg),
        "batch": args.batch,
        "num_params": int(nparams),
        "packed_len": int(model.packed_len(cfg)),
        "leaves": [
            {"name": n, "shape": list(s), "offset": int(off),
             "size": int(sz)}
            for (n, s, off, sz) in model.leaf_offsets(cfg)
        ],
        "calling_convention": {
            "train_step": {
                "inputs": "flat(f32[N]) + tokens(i32[batch,seq+1])",
                "outputs": "flat'(f32[N]); N = 3P+2, layout "
                           "[params|m|v|step|loss]",
            },
            "fwd_loss": {
                "inputs": "flat(f32[N]) + tokens(i32[batch,seq+1])",
                "outputs": "loss(f32[])",
            },
            "init_state": {
                "inputs": "seed(i32[])",
                "outputs": "flat(f32[N])",
            },
        },
        "attn_pallas": attn_shape,
        "adam_pallas": adam_shape,
        "hlo_text_bytes": outputs,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
